//! Kernel-layer microbenchmarks: GFLOP/s per kernel of the native
//! compute spine (DESIGN.md §9) — the MNIST forward/backward GEMMs, the
//! reversal attention kernels, and log-softmax — at the exact shapes the
//! testbed artifacts run. Results merge into `BENCH_e2e.json` (section
//! `kernels`) alongside the `e2e_step` entries, so the per-kernel and
//! end-to-end trajectories live in one committed file; override the path
//! with `KONDO_BENCH_JSON`.
//!
//! Entry convention: `mean_ns_per_step` is the mean wall-clock of ONE
//! kernel call at the stated shape, `throughput_per_s` is GFLOP/s
//! (`unit: "gflops"`), `workers` is always 1 (kernels are single-thread
//! primitives; parallelism lives a layer up in the worker pool). Every
//! dispatched kernel is benched as a `[scalar]`/`[dispatch]` column pair
//! — with `--features simd` on an AVX2 host the pair is the simd_off /
//! simd_on comparison; without the feature both columns run scalar and
//! the `simd` extra records 0. The two forward GEMMs add an `[f32fast]`
//! column for the non-golden f32 tier (DESIGN.md §13). Each cell also
//! carries a roofline-style `bytes_per_call` estimate (compulsory
//! traffic: operands read once + outputs written once) and the implied
//! `gbytes_per_s`, so a memory-bound kernel is readable as such straight
//! from the JSON.
//!
//! `cargo bench --bench kernels -- --autotune` switches to the autotune
//! sweep instead: it times `KernelTune` candidates (traversal blocking
//! only — reduction order is frozen, so every candidate is bit-identical)
//! at the testbed GEMM shapes and writes the winners as a tune file
//! (`KONDO_TUNE_OUT`, default `kernel_tune.txt`) for `KONDO_KERNEL_TUNE`.

mod bench_util;

use bench_util::{bench, JsonReport};
use kondo::runtime::kernels::{
    gather_mix_masked, gather_mix_masked_scalar, gemm_bias_logsoftmax,
    gemm_bias_logsoftmax_f32fast, gemm_bias_logsoftmax_scalar, gemm_bias_logsoftmax_with,
    gemm_bias_tanh, gemm_bias_tanh_f32fast, gemm_bias_tanh_scalar, gemm_bias_tanh_with,
    log_softmax_rows, log_softmax_rows_scalar, outer_acc, simd_enabled, softmax_jacobian_rows,
    softmax_jacobian_rows_scalar, softmax_rows, KernelTune, WeightPack, PANEL,
};
use kondo::runtime::native::{
    MNIST_ACTIONS, MNIST_BATCH, MNIST_HIDDEN, MNIST_IN, REV_HMAX, REV_VOCAB,
};
use kondo::utils::math::LANES;
use kondo::utils::rng::Pcg32;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Record one kernel cell: per-call latency, GFLOP/s from the analytic
/// flop count, and the roofline-style bytes-moved estimate of the benched
/// shape (`bytes` = compulsory traffic per call).
fn record(
    report: &mut JsonReport,
    section: &str,
    method: &str,
    mean_ns: f64,
    flops: f64,
    bytes: f64,
) {
    let gflops = flops / mean_ns; // flops per ns == GFLOP/s
    let gbps = bytes / mean_ns; // bytes per ns == GB/s
    report.record_with(
        section,
        method,
        1,
        mean_ns,
        gflops,
        "gflops",
        &[
            ("bytes_per_call", bytes),
            ("gbytes_per_s", gbps),
            ("simd", if simd_enabled() { 1.0 } else { 0.0 }),
        ],
    );
    println!("    -> {gflops:.3} GFLOP/s, {gbps:.3} GB/s ({bytes:.0} B/call)");
}

/// Compulsory GEMM traffic: x + packed weights (padded panels) + bias
/// read once, out written once. All f32.
fn gemm_bytes(rows: usize, k: usize, n: usize) -> f64 {
    let packed = n.div_ceil(PANEL) * k * PANEL;
    (4 * (rows * k + packed + n + rows * n)) as f64
}

fn main() {
    if std::env::args().any(|a| a == "--autotune") {
        autotune();
        return;
    }
    let platform = if simd_enabled() { "native+avx2" } else { "native" };
    let mut report = JsonReport::new("kernels", platform);
    let iters = 200;
    let warmup = 20;

    // ---- MNIST forward GEMM: [32, 784] x [784, 32], fused bias+tanh
    {
        let x = randv(MNIST_BATCH * MNIST_IN, 1);
        let w = randv(MNIST_IN * MNIST_HIDDEN, 2);
        let bias = randv(MNIST_HIDDEN, 3);
        let pack = WeightPack::new(&w, MNIST_IN, MNIST_HIDDEN, 0);
        let mut out = vec![0.0f32; MNIST_BATCH * MNIST_HIDDEN];
        let flops = 2.0 * (MNIST_BATCH * MNIST_IN * MNIST_HIDDEN) as f64;
        let bytes = gemm_bytes(MNIST_BATCH, MNIST_IN, MNIST_HIDDEN);
        let r = bench("mnist fwd gemm+tanh [32x784x32] scalar", iters, warmup, || {
            gemm_bias_tanh_scalar(&x, MNIST_BATCH, &pack, &bias, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "mnist_fwd", "gemm_bias_tanh_32x784x32[scalar]", r.mean_ns, flops, bytes);
        let r = bench("mnist fwd gemm+tanh [32x784x32] dispatch", iters, warmup, || {
            gemm_bias_tanh(&x, MNIST_BATCH, &pack, &bias, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "mnist_fwd", "gemm_bias_tanh_32x784x32[dispatch]", r.mean_ns, flops, bytes);
        let r = bench("mnist fwd gemm+tanh [32x784x32] f32fast", iters, warmup, || {
            gemm_bias_tanh_f32fast(&x, MNIST_BATCH, &pack, &bias, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "mnist_fwd", "gemm_bias_tanh_32x784x32[f32fast]", r.mean_ns, flops, bytes);
    }

    // ---- MNIST head GEMM: [32, 32] x [32, 10], fused bias+log-softmax
    {
        let h = randv(MNIST_BATCH * MNIST_HIDDEN, 4);
        let w = randv(MNIST_HIDDEN * MNIST_ACTIONS, 5);
        let bias = randv(MNIST_ACTIONS, 6);
        let pack = WeightPack::new(&w, MNIST_HIDDEN, MNIST_ACTIONS, 0);
        let mut out = vec![0.0f32; MNIST_BATCH * MNIST_ACTIONS];
        let flops = 2.0 * (MNIST_BATCH * MNIST_HIDDEN * MNIST_ACTIONS) as f64;
        let bytes = gemm_bytes(MNIST_BATCH, MNIST_HIDDEN, MNIST_ACTIONS);
        let r = bench("mnist head gemm+logsoftmax [32x32x10] scalar", iters, warmup, || {
            gemm_bias_logsoftmax_scalar(&h, MNIST_BATCH, &pack, &bias, None, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "mnist_fwd", "gemm_bias_logsoftmax_32x32x10[scalar]", r.mean_ns, flops, bytes);
        let r = bench("mnist head gemm+logsoftmax [32x32x10] dispatch", iters, warmup, || {
            gemm_bias_logsoftmax(&h, MNIST_BATCH, &pack, &bias, None, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "mnist_fwd", "gemm_bias_logsoftmax_32x32x10[dispatch]", r.mean_ns, flops, bytes);
        let r = bench("mnist head gemm+logsoftmax [32x32x10] f32fast", iters, warmup, || {
            gemm_bias_logsoftmax_f32fast(&h, MNIST_BATCH, &pack, &bias, None, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "mnist_fwd", "gemm_bias_logsoftmax_32x32x10[f32fast]", r.mean_ns, flops, bytes);
    }

    // ---- MNIST backward GEMM: the rank-1 g_w1 scatter, one batch of
    // per-sample outer products at the forward's shape (no SIMD twin:
    // the scatter stays scalar by design — DESIGN.md §13)
    {
        let xs = randv(MNIST_BATCH * MNIST_IN, 7);
        let dpre = randv(MNIST_HIDDEN, 8);
        let mut gw1 = vec![0.0f32; MNIST_IN * MNIST_HIDDEN];
        let r = bench("mnist bwd outer_acc x32 [784x32]", iters, warmup, || {
            for i in 0..MNIST_BATCH {
                outer_acc(&xs[i * MNIST_IN..(i + 1) * MNIST_IN], &dpre, &mut gw1);
            }
            std::hint::black_box(&mut gw1);
        });
        let flops = 2.0 * (MNIST_BATCH * MNIST_IN * MNIST_HIDDEN) as f64;
        // per sample: x and dpre read, gw read+written (accumulate)
        let bytes = (MNIST_BATCH * 4 * (MNIST_IN + MNIST_HIDDEN + 2 * MNIST_IN * MNIST_HIDDEN)) as f64;
        record(&mut report, "mnist_bwd", "outer_acc_batch32_784x32", r.mean_ns, flops, bytes);
    }

    // ---- reversal attention: gather-mix logits over a full episode
    // (h_max positions) plus the batched softmax-Jacobian backward
    {
        let attn = randv(REV_HMAX * REV_HMAX, 9);
        let mut alpha = vec![0.0f32; REV_HMAX * REV_HMAX];
        softmax_rows(&attn, REV_HMAX, REV_HMAX, &mut alpha);
        let emit = randv((REV_VOCAB + 1) * REV_VOCAB, 10);
        let idx: Vec<usize> = (0..REV_HMAX).map(|k| (k * 3) % (REV_VOCAB + 1)).collect();
        let mut acc = vec![0.0f64; REV_VOCAB * LANES];
        let mut logits = vec![0.0f32; REV_VOCAB];
        let flops = 2.0 * (REV_HMAX * REV_HMAX * REV_VOCAB) as f64;
        // per position: coef + gathered table rows read, acc (f64)
        // read+written per term, logits written once
        let bytes = (REV_HMAX
            * (4 * REV_HMAX
                + REV_HMAX * 4 * REV_VOCAB
                + REV_HMAX * 2 * 8 * REV_VOCAB * LANES
                + 4 * REV_VOCAB)) as f64;
        let mut run_pair = |label: &str,
                            method: &str,
                            f: &mut dyn FnMut(
            &[f32],
            &[f32],
            &[usize],
            &mut [f64],
            &mut [f32],
        )| {
            let r = bench(label, iters, warmup, || {
                for j in 0..REV_HMAX {
                    f(
                        &alpha[j * REV_HMAX..(j + 1) * REV_HMAX],
                        &emit,
                        &idx,
                        &mut acc,
                        &mut logits,
                    );
                    std::hint::black_box(&mut logits);
                }
            });
            record(&mut report, "rev_attention", method, r.mean_ns, flops, bytes);
        };
        run_pair(
            "rev attention gather_mix x8 [8x8] scalar",
            "gather_mix_8pos_8x8[scalar]",
            &mut |c, t, i, a, o| {
                gather_mix_masked_scalar(c, t, REV_VOCAB, i, REV_VOCAB, -1.0e30, a, o)
            },
        );
        run_pair(
            "rev attention gather_mix x8 [8x8] dispatch",
            "gather_mix_8pos_8x8[dispatch]",
            &mut |c, t, i, a, o| {
                gather_mix_masked(c, t, REV_VOCAB, i, REV_VOCAB, -1.0e30, a, o)
            },
        );

        let dalpha = randv(REV_HMAX * REV_HMAX, 11);
        let mut gattn = vec![0.0f32; REV_HMAX * REV_HMAX];
        // per row: a dot (2n) + n multiply-subtracts (2n)
        let flops = 4.0 * (REV_HMAX * REV_HMAX) as f64;
        let bytes = (3 * 4 * REV_HMAX * REV_HMAX) as f64;
        let r = bench("rev attention softmax_jacobian [8x8] scalar", iters, warmup, || {
            softmax_jacobian_rows_scalar(&alpha, &dalpha, REV_HMAX, REV_HMAX, &mut gattn);
            std::hint::black_box(&mut gattn);
        });
        record(&mut report, "rev_attention", "softmax_jacobian_8x8[scalar]", r.mean_ns, flops, bytes);
        let r = bench("rev attention softmax_jacobian [8x8] dispatch", iters, warmup, || {
            softmax_jacobian_rows(&alpha, &dalpha, REV_HMAX, REV_HMAX, &mut gattn);
            std::hint::black_box(&mut gattn);
        });
        record(&mut report, "rev_attention", "softmax_jacobian_8x8[dispatch]", r.mean_ns, flops, bytes);
    }

    // ---- log-softmax rows (single-pass logsumexp epilogue) at the MNIST
    // head shape
    {
        let logits = randv(MNIST_BATCH * MNIST_ACTIONS, 12);
        let mut out = vec![0.0f32; MNIST_BATCH * MNIST_ACTIONS];
        // per element: one exp-accumulate in the lse sweep + one subtract
        let flops = 3.0 * (MNIST_BATCH * MNIST_ACTIONS) as f64;
        // two read sweeps (lse + subtract) and one write, all f32
        let bytes = (3 * 4 * MNIST_BATCH * MNIST_ACTIONS) as f64;
        let r = bench("log_softmax_rows [32x10] scalar", iters, warmup, || {
            log_softmax_rows_scalar(&logits, MNIST_BATCH, MNIST_ACTIONS, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "log_softmax", "log_softmax_rows_32x10[scalar]", r.mean_ns, flops, bytes);
        let r = bench("log_softmax_rows [32x10] dispatch", iters, warmup, || {
            log_softmax_rows(&logits, MNIST_BATCH, MNIST_ACTIONS, &mut out);
            std::hint::black_box(&mut out);
        });
        record(&mut report, "log_softmax", "log_softmax_rows_32x10[dispatch]", r.mean_ns, flops, bytes);
    }

    let json_path = std::env::var("KONDO_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_e2e.json").to_string());
    report.write(&json_path);

    println!("\nexpected shape: the fwd GEMM dominated by the 784-wide reduction should");
    println!("sit near the scalar/dispatch roofline its gbytes_per_s column implies; the");
    println!("e2e_step bench tells whether those GFLOP/s survive the full pipeline.");
}

/// Autotune sweep: time `KernelTune` candidates at the testbed GEMM
/// shapes and write the winners as a `KONDO_KERNEL_TUNE` file. Blocking
/// only changes tile traversal order — every candidate produces
/// bit-identical output (locked by `gemm_is_tune_invariant_bitwise`) —
/// so picking the fastest is always safe.
fn autotune() {
    let iters = 100;
    let warmup = 10;
    let row_blocks = [1usize, 2, 4, 8, 16, 32];
    let panel_blocks = [1usize, 2, 4, 8, 16, 32];
    let mut lines = vec![
        "# shape-keyed kernel tune table: k n row_block panel_block".to_string(),
        format!("# emitted by `cargo bench --bench kernels -- --autotune` (simd={})", simd_enabled()),
    ];

    // shape 1: the hidden-layer GEMM [32, 784] x [784, 32]
    {
        let (rows, k, n) = (MNIST_BATCH, MNIST_IN, MNIST_HIDDEN);
        let x = randv(rows * k, 1);
        let w = randv(k * n, 2);
        let bias = randv(n, 3);
        let pack = WeightPack::new(&w, k, n, 0);
        let mut out = vec![0.0f32; rows * n];
        let mut best = (f64::INFINITY, KernelTune::DEFAULT);
        for &rb in &row_blocks {
            for &pb in &panel_blocks {
                let t = KernelTune { row_block: rb, panel_block: pb };
                let r = bench(&format!("tanh {k}x{n} rb={rb} pb={pb}"), iters, warmup, || {
                    gemm_bias_tanh_with(t, &x, rows, &pack, &bias, &mut out);
                    std::hint::black_box(&mut out);
                });
                if r.mean_ns < best.0 {
                    best = (r.mean_ns, t);
                }
            }
        }
        println!(
            "best for {k}x{n}: rb={} pb={} ({:.0} ns)",
            best.1.row_block, best.1.panel_block, best.0
        );
        lines.push(format!("{k} {n} {} {}", best.1.row_block, best.1.panel_block));
    }

    // shape 2: the head GEMM [32, 32] x [32, 10]
    {
        let (rows, k, n) = (MNIST_BATCH, MNIST_HIDDEN, MNIST_ACTIONS);
        let h = randv(rows * k, 4);
        let w = randv(k * n, 5);
        let bias = randv(n, 6);
        let pack = WeightPack::new(&w, k, n, 0);
        let mut out = vec![0.0f32; rows * n];
        let mut best = (f64::INFINITY, KernelTune::DEFAULT);
        for &rb in &row_blocks {
            for &pb in &panel_blocks {
                let t = KernelTune { row_block: rb, panel_block: pb };
                let r = bench(&format!("lsm {k}x{n} rb={rb} pb={pb}"), iters, warmup, || {
                    gemm_bias_logsoftmax_with(t, &h, rows, &pack, &bias, None, &mut out);
                    std::hint::black_box(&mut out);
                });
                if r.mean_ns < best.0 {
                    best = (r.mean_ns, t);
                }
            }
        }
        println!(
            "best for {k}x{n}: rb={} pb={} ({:.0} ns)",
            best.1.row_block, best.1.panel_block, best.0
        );
        lines.push(format!("{k} {n} {} {}", best.1.row_block, best.1.panel_block));
    }

    let out_path =
        std::env::var("KONDO_TUNE_OUT").unwrap_or_else(|_| "kernel_tune.txt".to_string());
    match std::fs::write(&out_path, lines.join("\n") + "\n") {
        Ok(()) => println!("\nwrote {out_path}; use it via KONDO_KERNEL_TUNE={out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
