//! Kernel-layer microbenchmarks: GFLOP/s per kernel of the native
//! compute spine (DESIGN.md §9) — the MNIST forward/backward GEMMs, the
//! reversal attention kernels, and log-softmax — at the exact shapes the
//! testbed artifacts run. Results merge into `BENCH_e2e.json` (section
//! `kernels`) alongside the `e2e_step` entries, so the per-kernel and
//! end-to-end trajectories live in one committed file; override the path
//! with `KONDO_BENCH_JSON`.
//!
//! Entry convention: `mean_ns_per_step` is the mean wall-clock of ONE
//! kernel call at the stated shape, `throughput_per_s` is GFLOP/s
//! (`unit: "gflops"`), `workers` is always 1 (kernels are single-thread
//! primitives; parallelism lives a layer up in the worker pool).

mod bench_util;

use bench_util::{bench, JsonReport};
use kondo::runtime::kernels::{
    gather_mix_masked, gemm_bias_logsoftmax, gemm_bias_tanh, log_softmax_rows, outer_acc,
    softmax_jacobian_rows, softmax_rows, WeightPack,
};
use kondo::runtime::native::{
    MNIST_ACTIONS, MNIST_BATCH, MNIST_HIDDEN, MNIST_IN, REV_HMAX, REV_VOCAB,
};
use kondo::utils::math::LANES;
use kondo::utils::rng::Pcg32;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Record one kernel cell: per-call latency plus GFLOP/s from the
/// analytic flop count of the benched shape.
fn record(report: &mut JsonReport, section: &str, method: &str, mean_ns: f64, flops: f64) {
    let gflops = flops / mean_ns; // flops per ns == GFLOP/s
    report.record(section, method, 1, mean_ns, gflops, "gflops");
    println!("    -> {gflops:.3} GFLOP/s");
}

fn main() {
    let mut report = JsonReport::new("kernels", "native");
    let iters = 200;
    let warmup = 20;

    // ---- MNIST forward GEMM: [32, 784] x [784, 32], fused bias+tanh
    {
        let x = randv(MNIST_BATCH * MNIST_IN, 1);
        let w = randv(MNIST_IN * MNIST_HIDDEN, 2);
        let bias = randv(MNIST_HIDDEN, 3);
        let pack = WeightPack::new(&w, MNIST_IN, MNIST_HIDDEN, 0);
        let mut out = vec![0.0f32; MNIST_BATCH * MNIST_HIDDEN];
        let r = bench("mnist fwd gemm+tanh [32x784x32]", iters, warmup, || {
            gemm_bias_tanh(&x, MNIST_BATCH, &pack, &bias, &mut out);
            std::hint::black_box(&mut out);
        });
        let flops = 2.0 * (MNIST_BATCH * MNIST_IN * MNIST_HIDDEN) as f64;
        record(&mut report, "mnist_fwd", "gemm_bias_tanh_32x784x32", r.mean_ns, flops);
    }

    // ---- MNIST head GEMM: [32, 32] x [32, 10], fused bias+log-softmax
    {
        let h = randv(MNIST_BATCH * MNIST_HIDDEN, 4);
        let w = randv(MNIST_HIDDEN * MNIST_ACTIONS, 5);
        let bias = randv(MNIST_ACTIONS, 6);
        let pack = WeightPack::new(&w, MNIST_HIDDEN, MNIST_ACTIONS, 0);
        let mut scratch = vec![0.0f32; MNIST_ACTIONS];
        let mut out = vec![0.0f32; MNIST_BATCH * MNIST_ACTIONS];
        let r = bench("mnist head gemm+logsoftmax [32x32x10]", iters, warmup, || {
            gemm_bias_logsoftmax(&h, MNIST_BATCH, &pack, &bias, None, &mut scratch, &mut out);
            std::hint::black_box(&mut out);
        });
        let flops = 2.0 * (MNIST_BATCH * MNIST_HIDDEN * MNIST_ACTIONS) as f64;
        record(&mut report, "mnist_fwd", "gemm_bias_logsoftmax_32x32x10", r.mean_ns, flops);
    }

    // ---- MNIST backward GEMM: the rank-1 g_w1 scatter, one batch of
    // per-sample outer products at the forward's shape
    {
        let xs = randv(MNIST_BATCH * MNIST_IN, 7);
        let dpre = randv(MNIST_HIDDEN, 8);
        let mut gw1 = vec![0.0f32; MNIST_IN * MNIST_HIDDEN];
        let r = bench("mnist bwd outer_acc x32 [784x32]", iters, warmup, || {
            for i in 0..MNIST_BATCH {
                outer_acc(&xs[i * MNIST_IN..(i + 1) * MNIST_IN], &dpre, &mut gw1);
            }
            std::hint::black_box(&mut gw1);
        });
        let flops = 2.0 * (MNIST_BATCH * MNIST_IN * MNIST_HIDDEN) as f64;
        record(&mut report, "mnist_bwd", "outer_acc_batch32_784x32", r.mean_ns, flops);
    }

    // ---- reversal attention: gather-mix logits over a full episode
    // (h_max positions) plus the batched softmax-Jacobian backward
    {
        let attn = randv(REV_HMAX * REV_HMAX, 9);
        let mut alpha = vec![0.0f32; REV_HMAX * REV_HMAX];
        softmax_rows(&attn, REV_HMAX, REV_HMAX, &mut alpha);
        let emit = randv((REV_VOCAB + 1) * REV_VOCAB, 10);
        let idx: Vec<usize> = (0..REV_HMAX).map(|k| (k * 3) % (REV_VOCAB + 1)).collect();
        let mut acc = vec![0.0f64; REV_VOCAB * LANES];
        let mut logits = vec![0.0f32; REV_VOCAB];
        let r = bench("rev attention gather_mix x8 [8x8]", iters, warmup, || {
            for j in 0..REV_HMAX {
                gather_mix_masked(
                    &alpha[j * REV_HMAX..(j + 1) * REV_HMAX],
                    &emit,
                    REV_VOCAB,
                    &idx,
                    REV_VOCAB,
                    -1.0e30,
                    &mut acc,
                    &mut logits,
                );
                std::hint::black_box(&mut logits);
            }
        });
        let flops = 2.0 * (REV_HMAX * REV_HMAX * REV_VOCAB) as f64;
        record(&mut report, "rev_attention", "gather_mix_8pos_8x8", r.mean_ns, flops);

        let dalpha = randv(REV_HMAX * REV_HMAX, 11);
        let mut gattn = vec![0.0f32; REV_HMAX * REV_HMAX];
        let r = bench("rev attention softmax_jacobian [8x8]", iters, warmup, || {
            softmax_jacobian_rows(&alpha, &dalpha, REV_HMAX, REV_HMAX, &mut gattn);
            std::hint::black_box(&mut gattn);
        });
        // per row: a dot (2n) + n multiply-subtracts (2n)
        let flops = 4.0 * (REV_HMAX * REV_HMAX) as f64;
        record(&mut report, "rev_attention", "softmax_jacobian_8x8", r.mean_ns, flops);
    }

    // ---- log-softmax rows (single-pass logsumexp epilogue) at the MNIST
    // head shape
    {
        let logits = randv(MNIST_BATCH * MNIST_ACTIONS, 12);
        let mut out = vec![0.0f32; MNIST_BATCH * MNIST_ACTIONS];
        let r = bench("log_softmax_rows [32x10]", iters, warmup, || {
            log_softmax_rows(&logits, MNIST_BATCH, MNIST_ACTIONS, &mut out);
            std::hint::black_box(&mut out);
        });
        // per element: one exp-accumulate in the lse sweep + one subtract
        let flops = 3.0 * (MNIST_BATCH * MNIST_ACTIONS) as f64;
        record(&mut report, "log_softmax", "log_softmax_rows_32x10", r.mean_ns, flops);
    }

    let json_path = std::env::var("KONDO_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_e2e.json").to_string());
    report.write(&json_path);

    println!("\nexpected shape: the fwd GEMM dominated by the 784-wide reduction should");
    println!("sit within a small factor of scalar-f64 peak; the e2e_step bench tells");
    println!("whether those GFLOP/s survive the full Screen -> Forward -> Gate -> Backward path.");
}
