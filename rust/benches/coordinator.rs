//! L3 hot-path microbenches: gate decision, quantile pricing, packing,
//! gather. Perf target (DESIGN.md §7): gate + pack must stay <= 5% of a
//! training step (i.e. well under 100 us at the observed ~2-10 ms steps).

mod bench_util;

use bench_util::bench;
use kondo::coordinator::{BucketSet, EwQuantile, KondoGate, P2Quantile, Priority};
use kondo::coordinator::batcher::gather_rows_f32;
use kondo::utils::rng::Pcg32;
use kondo::utils::stats::quantile_f32;

fn main() {
    let mut rng = Pcg32::seeded(0);
    let chi: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
    let chi_f32: Vec<f32> = chi.iter().map(|&c| c as f32).collect();

    // Algorithm 1 per batch: quantile pricing + Bernoulli gating (B = 100)
    let gate_rate = KondoGate::rate(0.03);
    bench("gate.decide rate=0.03 B=100", 50_000, 1000, || {
        std::hint::black_box(gate_rate.decide(&chi, &mut rng));
    });
    let gate_price = KondoGate::price(0.0);
    bench("gate.decide lambda=0 B=100", 50_000, 1000, || {
        std::hint::black_box(gate_price.decide(&chi, &mut rng));
    });
    let gate_soft = KondoGate::price(0.0).with_eta(0.5);
    bench("gate.decide soft eta=0.5 B=100", 50_000, 1000, || {
        std::hint::black_box(gate_soft.decide(&chi, &mut rng));
    });

    // pricing alternatives
    bench("quantile_f32 (1-rho) B=100", 50_000, 1000, || {
        std::hint::black_box(quantile_f32(&chi_f32, 0.97));
    });
    let mut p2 = P2Quantile::new(0.97);
    bench("P2Quantile.update", 200_000, 1000, || {
        p2.update(rng.normal());
    });
    let mut ew = EwQuantile::new(0.97, 0.05);
    bench("EwQuantile.update", 200_000, 1000, || {
        ew.update(rng.normal());
    });

    // priority scoring
    let u: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
    let ell: Vec<f64> = (0..100).map(|_| rng.uniform() + 0.1).collect();
    for pr in [Priority::Delight, Priority::Additive { alpha: 0.5 }, Priority::Uniform] {
        bench(&format!("priority.score_batch {} B=100", pr.name()), 50_000, 1000, || {
            std::hint::black_box(pr.score_batch(&u, &ell, &mut rng));
        });
    }

    // bucketed packing + gather (the x[kept, 784] marshaling of a step)
    let buckets = BucketSet::new(vec![4, 8, 16, 32, 64, 100]).unwrap();
    let kept: Vec<usize> = (0..3).map(|i| i * 17).collect();
    bench("buckets.pack kept=3", 200_000, 1000, || {
        std::hint::black_box(buckets.pack(&kept));
    });
    let x: Vec<f32> = (0..100 * 784).map(|i| i as f32).collect();
    bench("gather_rows_f32 3 of 100 x 784 -> cap 4", 50_000, 1000, || {
        std::hint::black_box(gather_rows_f32(&x, 784, &kept, 4));
    });
    let kept100: Vec<usize> = (0..100).collect();
    bench("gather_rows_f32 100 of 100 x 784 -> cap 100", 10_000, 100, || {
        std::hint::black_box(gather_rows_f32(&x, 784, &kept100, 100));
    });
}
