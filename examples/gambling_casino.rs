//! The gambling pathology, live (paper §4.2 / Proposition 3).
//!
//!     cargo run --release --example gambling_casino
//!
//! Simulates the paper's slot machine — arm 1 pays $1 always, arm 2 pays
//! $0 w.p. 0.99 and $50 w.p. 0.01 — and shows why delight-based screening
//! is fooled: a lucky draw on the bad arm produces a large positive
//! delight that no per-sample statistic can distinguish from a genuine
//! breakthrough (Remark 2). Pure tabular substrate; no artifacts needed.

use kondo::bandit_math::gambling_stats;
use kondo::coordinator::KondoGate;
use kondo::envs::bandit::GamblingBandit;
use kondo::metrics::ascii_table;
use kondo::utils::rng::Pcg32;

fn main() {
    // the paper's slot machine: mu* = 1, Delta = 0.5, sigma ~ 5, eps = 1%
    // (arm 2 pays 0 w.p. 0.99 / 50 w.p. 0.01 -> mean 0.5, sd ~ 4.97)
    println!("slot machine: arm 1 pays $1 always; arm 2 pays $0 (99%) or $50 (1%)");
    let mut rng = Pcg32::seeded(777);
    let gate = KondoGate::price(0.0);

    // --- empirical casino with the *actual* two-point payout
    let trials = 200_000;
    let eps = 0.01;
    let mut opened_on_bad = 0u64;
    let mut pulls_bad = 0u64;
    let mut chi_bad_max: f64 = 0.0;
    let baseline = 1.0 - eps * 0.5; // V^pi for the two-point machine
    for _ in 0..trials {
        let arm = if rng.bernoulli(eps) { 1 } else { 0 };
        if arm == 1 {
            pulls_bad += 1;
            let r = if rng.bernoulli(0.01) { 50.0 } else { 0.0 };
            let u = r - baseline;
            let ell = -(eps as f64).ln();
            let chi = u * ell;
            chi_bad_max = chi_bad_max.max(chi);
            if !gate.decide(&[chi], &mut rng).keep.is_empty() {
                opened_on_bad += 1;
            }
        }
    }
    println!(
        "\npulled the bad arm {pulls_bad} times; the zero-price Kondo gate opened on {opened_on_bad} of them ({:.2}%)",
        100.0 * opened_on_bad as f64 / pulls_bad.max(1) as f64
    );
    println!(
        "largest delight produced by a lucky draw: {chi_bad_max:.1} (a 'breakthrough' that isn't)"
    );

    // --- the Gaussian model of Prop 3, across sigma/delta regimes
    let mut rows = Vec::new();
    for &sigma in &[0.05, 0.15, 0.5, 1.5, 5.0] {
        let g = GamblingBandit::new(1.0, 0.5, sigma, eps);
        let st = gambling_stats(&g);
        rows.push(vec![
            format!("{:.1}", st.sigma_over_delta),
            format!("{:.4}", st.p_false_positive),
            format!("{:.1}", st.amplification),
            if st.sigma_over_delta < 1.0 { "reliable".into() } else { "pathological".into() },
        ]);
    }
    println!(
        "\n{}",
        ascii_table(
            &["sigma/Delta", "Pr(U2 > 0 | pull)", "delight amplification", "regime"],
            &rows
        )
    );
    println!(
        "Prop 3: under homoskedastic noise no arm is disproportionately amplified;\n\
         with differential sigma/Delta >> 1, lucky draws open the gate at Theta(1) rate\n\
         and delight multiplies them by log(1/eps) — an environmental limit, not an\n\
         algorithmic flaw (Remark 2)."
    );
}
