//! Why delight, not simpler priority signals? (paper §2.2 / Fig 5 mini)
//!
//!     make artifacts && cargo run --release --example priority_screening
//!
//! Trains the MNIST bandit with the same backward budget (3 samples per
//! 100) under five screening signals — delight, advantage-only,
//! surprisal-only, uniform random, and the additive mix — and prints the
//! final errors side by side. Delight targets the *intersection* of
//! valuable and unexpected; the alternatives chase one axis or mis-rank.

use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::{KondoGate, Priority};
use kondo::metrics::ascii_table;
use kondo::runtime::Engine;
use kondo::trainers::{train_mnist, MnistTrainerCfg};

fn main() -> anyhow::Result<()> {
    let eng = Engine::open("artifacts")?;
    let priorities = [
        Priority::Delight,
        Priority::Advantage,
        Priority::Surprisal,
        Priority::AbsAdvantage,
        Priority::Uniform,
        Priority::Additive { alpha: 0.5 },
    ];
    let mut rows = Vec::new();
    for pr in priorities {
        let cfg = MnistTrainerCfg {
            method: Method::DgK { gate: KondoGate::rate(0.03), priority: pr },
            baseline: Baseline::Expected,
            lr: 3e-4,
            steps: 800,
            eval_every: 100,
            eval_size: 500,
            seed: 0,
            ..Default::default()
        };
        let res = train_mnist(&eng, &cfg)?;
        rows.push(vec![
            pr.name(),
            format!("{:.3}", res.final_test_err),
            res.ledger.backward_kept.to_string(),
        ]);
        println!("{:<16} -> test err {:.3}", pr.name(), res.final_test_err);
    }
    println!(
        "\n{}",
        ascii_table(&["screening signal", "final test err", "bwd passes"], &rows)
    );
    println!("same backward budget everywhere; only the screening signal differs (Fig 5 / Prop 2)");
    Ok(())
}
