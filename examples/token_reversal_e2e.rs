//! End-to-end driver (DESIGN.md: the full-system validation example).
//!
//!     make artifacts && cargo run --release --example token_reversal_e2e
//!
//! Exercises every layer of the stack on the paper's sequence-model task:
//! the Pallas flash-attention kernel (L1) inside the compiled rollout, the
//! JAX transformer fwd/bwd artifacts (L2), and the Rust coordinator (L3:
//! Kondo gate -> bucketed backward -> Adam) — training the decoder-only
//! transformer on token reversal (H=10, M=2) for a few hundred steps with
//! both DG-K variants and PG, logging reward curves and the compute
//! ledger. The run is recorded in EXPERIMENTS.md §End-to-end.

use kondo::algo::Method;
use kondo::coordinator::{KondoGate, Priority};
use kondo::metrics::{ascii_curve, ascii_table, CsvWriter};
use kondo::runtime::Engine;
use kondo::trainers::{train_reversal, ReversalTrainerCfg};

fn main() -> anyhow::Result<()> {
    let eng = Engine::open("artifacts")?;
    println!("platform: {} | token reversal H=10 M=2, 300 steps x 100 episodes", eng.platform());

    let methods: Vec<(&str, Method)> = vec![
        ("pg", Method::Pg),
        ("dg", Method::Dg),
        ("dgk_rho3", Method::DgK {
            gate: KondoGate::rate(0.03),
            priority: Priority::Delight,
        }),
        ("dgk_lam0", Method::DgK {
            gate: KondoGate::price(0.0),
            priority: Priority::Delight,
        }),
    ];

    let mut w = CsvWriter::create(
        "results/e2e/token_reversal.csv",
        &["method", "step", "fwd_tokens", "bwd_tokens_kept", "bwd_tokens_executed", "reward"],
    )?;
    let mut rows = Vec::new();
    for (name, method) in methods {
        let cfg = ReversalTrainerCfg {
            method,
            lr: 3e-4,
            steps: 300,
            h: 10,
            m: 2,
            seed: 0,
            eval_every: 15,
            inner_epochs: 1,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let res = train_reversal(&eng, &cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        for p in &res.curve {
            w.row(&[
                name.to_string(),
                p.step.to_string(),
                p.forward_samples.to_string(),
                p.backward_kept.to_string(),
                p.backward_executed.to_string(),
                format!("{:.4}", p.metric),
            ])?;
        }
        let steps: Vec<f64> = res.curve.iter().map(|p| p.step as f64).collect();
        let rs: Vec<f64> = res.curve.iter().map(|p| p.metric).collect();
        print!("{}", ascii_curve(&format!("{name} reward"), &steps, &rs, 48));
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", res.final_reward),
            res.ledger.backward_kept.to_string(),
            res.ledger.backward_executed.to_string(),
            format!("{:.0}s", secs),
        ]);
    }
    println!(
        "\n{}",
        ascii_table(
            &["method", "final reward", "bwd tokens kept", "bwd tokens executed", "wall"],
            &rows
        )
    );
    println!("curves written to results/e2e/token_reversal.csv");

    println!("\nartifact timings:");
    for (name, st) in eng.stats() {
        if st.calls > 0 {
            println!(
                "  {name:<16} {:>6} calls  {:>8.1} ms/call",
                st.calls,
                1e3 * st.total_secs / st.calls as f64
            );
        }
    }
    Ok(())
}
