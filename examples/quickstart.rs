//! Quickstart: train the MNIST-bandit policy with the Kondo gate in ~30s.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Trains DG-K (rho = 3%) against plain PG for a few hundred steps and
//! prints both learning curves plus the backward-pass ledger — the
//! paper's headline phenomenon in miniature: nearly the same learning,
//! a fraction of the backward compute.

use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::{KondoGate, Priority};
use kondo::metrics::ascii_curve;
use kondo::runtime::Engine;
use kondo::trainers::{train_mnist, MnistTrainerCfg};

fn main() -> anyhow::Result<()> {
    let eng = Engine::open("artifacts")?;
    println!("platform: {} | artifacts loaded", eng.platform());

    // a glimpse of the synthetic digit corpus (the MNIST substitution)
    use kondo::envs::digits::{ascii_digit, DigitCorpus, Split};
    let corpus = DigitCorpus::new(1234);
    let a = ascii_digit(&corpus.image(Split::Train, 3));
    let b = ascii_digit(&corpus.image(Split::Train, 7));
    for (la, lb) in a.lines().zip(b.lines()) {
        println!("{la}   {lb}");
    }
    println!("two corpus samples: a '3' and a '7'\n");

    let mut results = Vec::new();
    for (name, method) in [
        ("PG", Method::Pg),
        ("DG-K rho=3%", Method::DgK {
            gate: KondoGate::rate(0.03),
            priority: Priority::Delight,
        }),
    ] {
        let cfg = MnistTrainerCfg {
            method,
            baseline: Baseline::Expected,
            lr: 3e-4,
            steps: 600,
            eval_every: 50,
            eval_size: 500,
            seed: 0,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let res = train_mnist(&eng, &cfg)?;
        println!(
            "\n{name}: trained {} steps in {:.1}s",
            cfg.steps,
            t0.elapsed().as_secs_f64()
        );
        let steps: Vec<f64> = res.curve.iter().map(|p| p.step as f64).collect();
        let errs: Vec<f64> = res.curve.iter().map(|p| p.metric2).collect();
        print!("{}", ascii_curve(&format!("{name} test err"), &steps, &errs, 48));
        println!(
            "  final test err {:.3} | backward passes {} / {} forward ({}x reduction)",
            res.final_test_err,
            res.ledger.backward_kept,
            res.ledger.forward_samples,
            res.ledger.forward_samples / res.ledger.backward_kept.max(1)
        );
        results.push((name, res));
    }

    let (_, pg) = &results[0];
    let (_, kg) = &results[1];
    println!(
        "\nKondo gate: {:.1}x fewer backward passes, test err {:.3} vs PG {:.3}",
        pg.ledger.backward_kept as f64 / kg.ledger.backward_kept.max(1) as f64,
        kg.final_test_err,
        pg.final_test_err
    );
    Ok(())
}
