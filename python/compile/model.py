"""L2 export surface: flat-argument wrappers around the models.

Artifacts take parameters as individual leading arguments (in
``PARAM_ORDER``) followed by data inputs, so the Rust runtime can marshal
them positionally from its parameter store. aot.py lowers each function
here to one HLO-text artifact; the (name, shape, dtype) signature of every
artifact is recorded in ``manifest.json``.
"""

import jax.numpy as jnp

from . import config as C
from .models import mlp, transformer

# ---------------------------------------------------------------- MNIST MLP

N_MLP = len(mlp.PARAM_ORDER)


def _mlp_params(args):
    return dict(zip(mlp.PARAM_ORDER, args))


def mnist_fwd(*args):
    """(params..., x[B,784], noise[B,10]) -> (logp[B,10],)"""
    p = _mlp_params(args[:N_MLP])
    x, noise = args[N_MLP:]
    return (mlp.forward_logprobs(p, x, noise),)


def mnist_fwd_eval(*args):
    """(params..., x[Be,784]) -> (logp[Be,10],) -- zero-noise eval pass."""
    p = _mlp_params(args[:N_MLP])
    (x,) = args[N_MLP:]
    noise = jnp.zeros((x.shape[0], C.MNIST_ACTIONS))
    return (mlp.forward_logprobs(p, x, noise),)


def mnist_bwd(*args):
    """(params..., x[c,784], a[c], w[c]) -> (loss[1], grads...)"""
    p = _mlp_params(args[:N_MLP])
    x, actions, weights = args[N_MLP:]
    out = mlp.backward(p, x, actions, weights)
    return (out[0].reshape(1),) + out[1:]


# ------------------------------------------------------------ Token reversal
# One wrapper set per compiled h_max (config.REV_SETS).


def _tf_params(args, h_max):
    order = transformer.param_order(h_max)
    return dict(zip(order, args[: len(order)])), len(order)


def rev_rollout(h_max, *args):
    """(params..., prompt i32[B,Hm], h i32[1], m i32[1], seed i32[1])
    -> (actions i32[B,Hm], logp f32[B,Hm])"""
    p, n = _tf_params(args, h_max)
    prompt, h, m, seed = args[n:]
    return transformer.rollout(p, prompt, h[0], m[0], seed[0], h_max)


def rev_fwd(h_max, *args):
    """(params..., prompt, actions, h[1], m[1]) -> (logp f32[B,Hm],)"""
    p, n = _tf_params(args, h_max)
    prompt, actions, h, m = args[n:]
    return (transformer.teacher_logp(p, prompt, actions, h[0], m[0], h_max),)


def rev_bwd(h_max, *args):
    """(params..., prompt[c,Hm], actions[c,Hm], w[c,Hm], h[1], m[1])
    -> (loss[1], grads...)"""
    p, n = _tf_params(args, h_max)
    prompt, actions, weights, h, m = args[n:]
    out = transformer.backward(p, prompt, actions, weights, h[0], m[0], h_max)
    return (out[0].reshape(1),) + out[1:]
