"""AOT lowering: every L2 entry point -> HLO *text* artifact + manifest.

Run once by ``make artifacts`` (never on the request path):

    cd python && python -m compile.aot --out ../artifacts

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` 0.1.6 crate links) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Besides the ``.hlo.txt`` files this writes ``manifest.json``: for every
artifact its positional input/output signature, for every model its
parameter tensors in artifact-argument order with their init rule (the
Rust side re-initializes parameters per seed from these rules), and the
static shape constants. The Rust runtime refuses to run against a manifest
whose constants disagree with its own config.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config as C
from . import model
from .models import mlp, transformer

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": "i32" if dtype == I32 else "f32"}


def _param_inputs(param_specs):
    return [_sig(n, s, F32) for n, s in param_specs]


def _init_rules(param_specs, model_name):
    """Per-tensor init rule mirrored by rust/src/model (normal / zeros / ones)."""
    rules = []
    for name, shape in param_specs:
        if model_name == "mnist":
            if name == "w1":
                rule = {"kind": "normal", "scale": float(np.sqrt(2.0 / C.MNIST_IN))}
            elif name in ("w2", "w3"):
                rule = {"kind": "normal", "scale": float(np.sqrt(2.0 / C.MNIST_HIDDEN))}
            else:
                rule = {"kind": "zeros"}
        else:
            if "ln" in name and name.endswith("_s"):
                rule = {"kind": "ones"}
            elif len(shape) == 1:
                rule = {"kind": "zeros"}
            else:
                rule = {"kind": "normal", "scale": 0.02}
        rules.append({"name": name, "shape": list(shape), **rule})
    return rules


def build_artifacts():
    """Returns {name: (fn, [input ShapeDtypeStructs], [input sigs], [output sigs])}."""
    arts = {}
    mlp_p = [spec(s) for _, s in mlp.PARAM_SPECS]
    mlp_sig = _param_inputs(mlp.PARAM_SPECS)
    B, Be, A = C.MNIST_BATCH, C.MNIST_EVAL_BATCH, C.MNIST_ACTIONS

    arts["mnist_fwd"] = (
        model.mnist_fwd,
        mlp_p + [spec((B, C.MNIST_IN)), spec((B, A))],
        mlp_sig + [_sig("x", (B, C.MNIST_IN), F32), _sig("logit_noise", (B, A), F32)],
        [_sig("logp", (B, A), F32)],
    )
    arts["mnist_fwd_eval"] = (
        model.mnist_fwd_eval,
        mlp_p + [spec((Be, C.MNIST_IN))],
        mlp_sig + [_sig("x", (Be, C.MNIST_IN), F32)],
        [_sig("logp", (Be, A), F32)],
    )
    grad_outs = [_sig("loss", (1,), F32)] + [_sig(f"g_{n}", s, F32) for n, s in mlp.PARAM_SPECS]
    for cap in C.MNIST_BWD_CAPS:
        arts[f"mnist_bwd_c{cap}"] = (
            model.mnist_bwd,
            mlp_p + [spec((cap, C.MNIST_IN)), spec((cap,), I32), spec((cap,))],
            mlp_sig
            + [
                _sig("x", (cap, C.MNIST_IN), F32),
                _sig("actions", (cap,), I32),
                _sig("weights", (cap,), F32),
            ],
            grad_outs,
        )

    import functools

    for h_max in C.REV_SETS:
        pre = f"rev{h_max}"
        specs = transformer.param_specs(h_max)
        tf_p = [spec(s) for _, s in specs]
        tf_sig = _param_inputs(specs)
        Rb, Hm = C.REV_BATCH, h_max

        arts[f"{pre}_rollout"] = (
            functools.partial(model.rev_rollout, h_max),
            tf_p + [spec((Rb, Hm), I32), spec((1,), I32), spec((1,), I32), spec((1,), I32)],
            tf_sig
            + [
                _sig("prompt", (Rb, Hm), I32),
                _sig("h", (1,), I32),
                _sig("m", (1,), I32),
                _sig("seed", (1,), I32),
            ],
            [_sig("actions", (Rb, Hm), I32), _sig("logp", (Rb, Hm), F32)],
        )
        arts[f"{pre}_fwd"] = (
            functools.partial(model.rev_fwd, h_max),
            tf_p
            + [spec((Rb, Hm), I32), spec((Rb, Hm), I32), spec((1,), I32), spec((1,), I32)],
            tf_sig
            + [
                _sig("prompt", (Rb, Hm), I32),
                _sig("actions", (Rb, Hm), I32),
                _sig("h", (1,), I32),
                _sig("m", (1,), I32),
            ],
            [_sig("logp", (Rb, Hm), F32)],
        )
        tf_grad_outs = [_sig("loss", (1,), F32)] + [
            _sig(f"g_{n}", s, F32) for n, s in specs
        ]
        for cap in C.REV_BWD_CAPS:
            arts[f"{pre}_bwd_c{cap}"] = (
                functools.partial(model.rev_bwd, h_max),
                tf_p
                + [
                    spec((cap, Hm), I32),
                    spec((cap, Hm), I32),
                    spec((cap, Hm)),
                    spec((1,), I32),
                    spec((1,), I32),
                ],
                tf_sig
                + [
                    _sig("prompt", (cap, Hm), I32),
                    _sig("actions", (cap, Hm), I32),
                    _sig("weights", (cap, Hm), F32),
                    _sig("h", (1,), I32),
                    _sig("m", (1,), I32),
                ],
                tf_grad_outs,
            )
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arts = build_artifacts()
    only = set(args.only.split(",")) if args.only else None
    manifest = {
        "constants": {
            "mnist_batch": C.MNIST_BATCH,
            "mnist_eval_batch": C.MNIST_EVAL_BATCH,
            "mnist_actions": C.MNIST_ACTIONS,
            "mnist_in": C.MNIST_IN,
            "mnist_bwd_caps": list(C.MNIST_BWD_CAPS),
            "rev_batch": C.REV_BATCH,
            "rev_sets": list(C.REV_SETS),
            "h_max": C.H_MAX,
            "vocab": C.VOCAB,
            "pad": C.PAD,
            "rev_bwd_caps": list(C.REV_BWD_CAPS),
            "neg_inf": C.NEG_INF,
        },
        "models": {
            "mnist": {"params": _init_rules(mlp.PARAM_SPECS, "mnist")},
            **{
                f"reversal{hm}": {
                    "params": _init_rules(transformer.param_specs(hm), "reversal")
                }
                for hm in C.REV_SETS
            },
        },
        "artifacts": {},
    }

    for name, (fn, in_specs, in_sigs, out_sigs) in arts.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": in_sigs,
            "outputs": out_sigs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"[aot] {name}: {len(text)} chars -> {path}")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest -> {mpath}")


if __name__ == "__main__":
    main()
