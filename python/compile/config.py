"""Static model / artifact configuration shared by L1 kernels, L2 models and aot.py.

These constants define the single compiled shape-set. Smaller problem sizes
(H <= H_MAX, M <= VOCAB) are expressed at run time through masks fed to the
artifacts as data, so one artifact set serves every sweep point in the paper
(Figures 8-10, 18-21).
"""

# ---------------------------------------------------------------- MNIST MLP
MNIST_IN = 784          # 28*28 images
MNIST_HIDDEN = 100      # paper App A.1: two hidden layers of 100 units
MNIST_ACTIONS = 10      # digits 0..9
MNIST_BATCH = 100       # paper App A.1: B = 100
MNIST_EVAL_BATCH = 500  # evaluation chunk size (test set is streamed in chunks)
# Capacity buckets for the gated backward executor (L3 packs kept samples
# into the smallest bucket >= kept count). rho=0.03 of B=100 -> bucket 4.
MNIST_BWD_CAPS = (4, 8, 16, 32, 64, 100)

# ------------------------------------------------------ Token reversal model
D_MODEL = 64            # paper App D.1
N_LAYERS = 2
N_HEADS = 2
D_HEAD = D_MODEL // N_HEADS
D_FF = 4 * D_MODEL
# Two compiled shape sets: a fast one for H <= 16 (most sweeps) and the
# full one for the long-sequence scaling axis (paper sweeps H <= 30).
# Each set has sequence length SEQ = 2*h_max (prompt half + response half).
REV_SETS = (16, 32)
H_MAX = max(REV_SETS)   # largest supported sequence
VOCAB = 64              # largest supported vocabulary (paper sweeps M <= 64)
PAD = VOCAB             # pad token id (input-embedding only, never an action)
VOCAB_IN = VOCAB + 1    # input embedding table includes PAD
REV_BATCH = 100         # paper App D.1: P=10 prompts x S=10 responses
REV_BWD_CAPS = (13, 25, 50, 100)

NEG_INF = -1e30         # additive-mask negative (finite: avoids NaN in softmax)
