"""L1 Pallas kernel: causally-masked flash attention, VMEM-tiled.

TPU adaptation of the paper's sequence-model training path (DESIGN.md
par.4): the HBM<->VMEM schedule a CUDA kernel would express with
threadblocks/shared memory is expressed here with a BlockSpec grid
(batch*heads, q-blocks, k-blocks) and the running-softmax recurrence in
VMEM scratch. Inputs collapse batch and heads into one leading dim.

`flash_attention` is a `jax.custom_vjp`: forward runs the Pallas kernel;
backward recomputes attention probabilities with plain jnp and applies the
standard analytic gradients (flash-attention bwd without dedicated kernel
-- correctness-first; see DESIGN.md par.7 for the perf plan).

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..config import NEG_INF


def _pick_block(n, target):
    for cand in range(min(n, target), 0, -1):
        if n % cand == 0:
            return cand
    return n


def _kernel(q_ref, k_ref, v_ref, pm_ref, o_ref, m_scr, l_scr, acc_scr, *, bq, bk, dh):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = (q @ k.T) * (1.0 / np.sqrt(dh))

    # Causal mask from global indices; key padding mask is additive input.
    qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(kj <= qi, s, NEG_INF)
    s = s + pm_ref[0][None, :]

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(jk == nk - 1)
    def _fin():
        o_ref[0] = acc_scr[...] / l_scr[...][:, None]


def _flash_raw(q, k, v, pad_add, block_q, block_k):
    bh, t, dh = q.shape
    bq = _pick_block(t, block_q)
    bk = _pick_block(t, block_k)
    kern = functools.partial(_kernel, bq=bq, bk=bk, dh=dh)
    return pl.pallas_call(
        kern,
        grid=(bh, t // bq, t // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, pad_add)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def flash_attention(q, k, v, pad_add, block_q=32, block_k=32):
    """Causal attention with key-pad mask. q,k,v: [BH,T,Dh]; pad_add: [BH,T]."""
    return _flash_raw(q, k, v, pad_add, block_q, block_k)


def _fwd(q, k, v, pad_add, block_q, block_k):
    out = _flash_raw(q, k, v, pad_add, block_q, block_k)
    return out, (q, k, v, pad_add)


def _bwd(block_q, block_k, res, do):
    q, k, v, pad_add = res
    t = q.shape[1]
    dh = q.shape[2]
    scale = 1.0 / np.sqrt(dh)
    s = q @ jnp.swapaxes(k, -1, -2) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(causal[None, :, :], s, NEG_INF)
    s = s + pad_add[:, None, :]
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.swapaxes(p, -1, -2) @ do
    dp = do @ jnp.swapaxes(v, -1, -2)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    # Replicate autodiff-of-ref exactly: `jnp.where(causal, ...)` blocks the
    # cotangent at causally-masked entries. This matters only in degenerate
    # all-masked query rows (pad positions), where softmax is uniform over
    # equally -inf entries and ds is not numerically zero.
    ds = jnp.where(causal[None, :, :], ds, 0.0)
    dq = (ds @ k) * scale
    dk = (jnp.swapaxes(ds, -1, -2) @ q) * scale
    # pad_add is a mask, not a trainable input: zero cotangent.
    return dq, dk, dv, jnp.zeros_like(pad_add)


flash_attention.defvjp(_fwd, _bwd)
