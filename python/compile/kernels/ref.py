"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has an exact reference here; pytest asserts
allclose between the two across hypothesis-generated shapes and dtypes.
These are also the implementations the custom-VJP backward rules are
derived from, so kernel-vs-ref agreement implies gradient correctness.
"""

import jax
import jax.numpy as jnp

from ..config import NEG_INF


def head_logprobs(h, w, b, extra):
    """log_softmax(h @ w.T + b + extra) over the last axis.

    h: [N, D] activations; w: [V, D] head weights; b: [V] bias;
    extra: [N, V] additive term (logit noise / vocab mask). Returns [N, V].
    """
    logits = h @ w.T + b[None, :] + extra
    return jax.nn.log_softmax(logits, axis=-1)


def head_action_logprobs(h, w, b, actions, extra):
    """log pi(a) for the chosen action only: [N]."""
    logp = head_logprobs(h, w, b, extra)
    return jnp.take_along_axis(logp, actions[:, None], axis=1)[:, 0]


def attention(q, k, v, pad_add):
    """Causal softmax attention with additive key padding mask.

    q, k, v: [BH, T, Dh] (batch*heads collapsed); pad_add: [BH, T] additive
    mask applied to keys (0 for valid, NEG_INF for padded). Returns [BH, T, Dh].
    """
    t = q.shape[1]
    dh = q.shape[2]
    s = q @ jnp.swapaxes(k, -1, -2) * (1.0 / jnp.sqrt(jnp.float32(dh)))
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(causal[None, :, :], s, NEG_INF)
    s = s + pad_add[:, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return p @ v
