"""L1 Pallas kernel: fused classifier head with streaming log-softmax.

This is the paper's "cheap screening pass" made cheap at the kernel level
(DESIGN.md par.4). The head projection `h @ w.T + b` is tiled over vocab
blocks sized for VMEM; a running (max, sumexp) pair per row implements the
flash-attention recurrence applied to log-softmax, so surprisal / delight
inputs are produced in a single MXU pass without re-reading logits from HBM.

Two entry points:

- ``head_logprobs(h, w, b, extra)``       -> full log-probs [N, V]
  (needed where the coordinator samples actions from the distribution).
- ``head_action_logprobs(h, w, b, a, extra)`` -> chosen-action log-probs [N]
  (the pure screening/training path: the [N, V] logit tensor is never
  materialized in HBM -- only per-row accumulators and the output [N]).

Both are `jax.custom_vjp` so the same kernels sit on the differentiated
training path; backward rules are the exact analytic gradients of
`ref.head_logprobs` (the select variant recomputes the [N, V] softmax in
the backward, a deliberate rematerialization trade documented in
DESIGN.md par.7/L2).

Kernels run with ``interpret=True``: CPU PJRT cannot execute Mosaic
custom-calls; structure (BlockSpec schedule) is TPU-shaped, numerics are
validated on CPU against ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pick_block(n, target):
    """Largest divisor of n that is <= target (TPU lane-friendly when possible)."""
    for cand in range(min(n, target), 0, -1):
        if n % cand == 0:
            return cand
    return n


# --------------------------------------------------------------------------
# Full log-probs kernel: logits [N, V] + row logsumexp [N] in one sweep.
# --------------------------------------------------------------------------

def _full_kernel(h_ref, w_ref, b_ref, e_ref, out_ref, lse_ref, m_scr, l_scr):
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)

    # MXU tile: [bB, D] @ [D, bV] plus bias and additive extra (noise/mask).
    logits = h_ref[...] @ w_ref[...].T + b_ref[...][None, :] + e_ref[...]
    out_ref[...] = logits

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(logits - m_new[:, None]), axis=1
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nv - 1)
    def _fin():
        lse_ref[...] = m_scr[...] + jnp.log(l_scr[...])


def _full_raw(h, w, b, extra, block_b, block_v):
    n, d = h.shape
    v = w.shape[0]
    bb = _pick_block(n, block_b)
    bv = _pick_block(v, block_v)
    logits, lse = pl.pallas_call(
        _full_kernel,
        grid=(n // bb, v // bv),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bv,), lambda i, j: (j,)),
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, v), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
        ],
        interpret=True,
    )(h, w, b, extra)
    return logits - lse[:, None]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def head_logprobs(h, w, b, extra, block_b=32, block_v=128):
    """log_softmax(h @ w.T + b + extra): [N, V], Pallas-fused."""
    return _full_raw(h, w, b, extra, block_b, block_v)


def _full_fwd(h, w, b, extra, block_b, block_v):
    logp = _full_raw(h, w, b, extra, block_b, block_v)
    return logp, (h, w, logp)


def _full_bwd(block_b, block_v, res, g):
    h, w, logp = res
    p = jnp.exp(logp)
    dlogits = g - p * jnp.sum(g, axis=-1, keepdims=True)
    dh = dlogits @ w
    dw = dlogits.T @ h
    db = jnp.sum(dlogits, axis=0)
    return dh, dw, db, dlogits


head_logprobs.defvjp(_full_fwd, _full_bwd)


# --------------------------------------------------------------------------
# Select kernel: chosen-action log-probs only -- the streaming screen.
# The [N, V] logits never leave VMEM; per-row accumulators carry
# (running max, running sumexp, chosen logit) across vocab blocks.
# --------------------------------------------------------------------------

def _sel_kernel(h_ref, w_ref, b_ref, a_ref, e_ref, out_ref, m_scr, l_scr, a_scr):
    j = pl.program_id(1)
    nv = pl.num_programs(1)
    bv = w_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        a_scr[...] = jnp.zeros_like(a_scr)

    logits = h_ref[...] @ w_ref[...].T + b_ref[...][None, :] + e_ref[...]

    # Each action index lands in exactly one vocab block: accumulate its logit.
    local = a_ref[...] - j * bv
    hit = (local >= 0) & (local < bv)
    safe = jnp.clip(local, 0, bv - 1)
    picked = jnp.take_along_axis(logits, safe[:, None], axis=1)[:, 0]
    a_scr[...] = a_scr[...] + jnp.where(hit, picked, 0.0)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(logits - m_new[:, None]), axis=1
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nv - 1)
    def _fin():
        out_ref[...] = a_scr[...] - (m_scr[...] + jnp.log(l_scr[...]))


def _sel_raw(h, w, b, actions, extra, block_b, block_v):
    n, d = h.shape
    v = w.shape[0]
    bb = _pick_block(n, block_b)
    bv = _pick_block(v, block_v)
    return pl.pallas_call(
        _sel_kernel,
        grid=(n // bb, v // bv),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bv,), lambda i, j: (j,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
        ],
        interpret=True,
    )(h, w, b, actions, extra)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def head_action_logprobs(h, w, b, actions, extra, block_b=32, block_v=128):
    """log pi(a) for chosen actions: [N], without materializing [N, V]."""
    return _sel_raw(h, w, b, actions, extra, block_b, block_v)


def _sel_fwd(h, w, b, actions, extra, block_b, block_v):
    out = _sel_raw(h, w, b, actions, extra, block_b, block_v)
    return out, (h, w, b, actions, extra)


def _sel_bwd(block_b, block_v, res, g):
    # Deliberate rematerialization: the backward recomputes softmax [N, V]
    # with plain jnp (XLA fuses it); grad of gathered log-softmax is
    # g * (onehot(a) - softmax).
    h, w, b, actions, extra = res
    v = w.shape[0]
    logits = h @ w.T + b[None, :] + extra
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(actions, v, dtype=h.dtype)
    dlogits = g[:, None] * (onehot - p)
    dh = dlogits @ w
    dw = dlogits.T @ h
    db = jnp.sum(dlogits, axis=0)
    return dh, dw, db, None, dlogits


head_action_logprobs.defvjp(_sel_fwd, _sel_bwd)
