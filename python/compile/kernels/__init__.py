# L1: Pallas kernels for the paper's compute hot-spots.
from .attention import flash_attention
from .fused_head import head_action_logprobs, head_logprobs

__all__ = ["flash_attention", "head_logprobs", "head_action_logprobs"]
