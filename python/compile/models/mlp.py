"""L2: MNIST contextual-bandit policy -- two-layer MLP (paper App A.1).

Architecture: 784 -> 100 -> 100 -> softmax(10), ReLU activations. The head
is the L1 fused streaming-log-softmax Pallas kernel, so the forward pass
that produces the gate's screening signal is the optimized path.

The backward artifact computes grad of  L(theta) = -sum_i w_i log pi(a_i|x_i)
for per-sample weights w supplied by the L3 coordinator. Every method in
the paper (PG / DG / DG-K / PPO / PMPO) reduces to a choice of w, so a
single compiled backward serves all of them (DESIGN.md par.2 algo/).
"""

import jax
import jax.numpy as jnp

from .. import config as C
from ..kernels import head_action_logprobs, head_logprobs

# Parameter tensors in artifact-argument order (manifest `models.mnist.params`).
PARAM_SPECS = [
    ("w1", (C.MNIST_IN, C.MNIST_HIDDEN)),
    ("b1", (C.MNIST_HIDDEN,)),
    ("w2", (C.MNIST_HIDDEN, C.MNIST_HIDDEN)),
    ("b2", (C.MNIST_HIDDEN,)),
    ("w3", (C.MNIST_ACTIONS, C.MNIST_HIDDEN)),  # [V, D] for the fused head
    ("b3", (C.MNIST_ACTIONS,)),
]
PARAM_ORDER = [name for name, _ in PARAM_SPECS]


def init_params(key):
    """He-normal init for ReLU layers, zero biases (matches companion setup)."""
    ks = jax.random.split(key, 3)
    p = {}
    p["w1"] = jax.random.normal(ks[0], PARAM_SPECS[0][1]) * jnp.sqrt(2.0 / C.MNIST_IN)
    p["b1"] = jnp.zeros(PARAM_SPECS[1][1])
    p["w2"] = jax.random.normal(ks[1], PARAM_SPECS[2][1]) * jnp.sqrt(2.0 / C.MNIST_HIDDEN)
    p["b2"] = jnp.zeros(PARAM_SPECS[3][1])
    p["w3"] = jax.random.normal(ks[2], PARAM_SPECS[4][1]) * jnp.sqrt(2.0 / C.MNIST_HIDDEN)
    p["b3"] = jnp.zeros(PARAM_SPECS[5][1])
    return p


def _trunk(p, x):
    h1 = jax.nn.relu(x @ p["w1"] + p["b1"])
    h2 = jax.nn.relu(h1 @ p["w2"] + p["b2"])
    return h2


def forward_logprobs(p, x, logit_noise):
    """Full policy distribution log pi(.|x): [B, 10].

    `logit_noise` [B, 10] is added to logits pre-softmax (zeros normally;
    N(0, sigma_Z^2) for the Fig 4b robustness experiment).
    """
    h2 = _trunk(p, x)
    return head_logprobs(h2, p["w3"], p["b3"], logit_noise)


def weighted_loss(p, x, actions, weights):
    """-sum_i w_i log pi(a_i | x_i); grads of this are the policy update."""
    h2 = _trunk(p, x)
    extra = jnp.zeros((x.shape[0], C.MNIST_ACTIONS), dtype=jnp.float32)
    logp_a = head_action_logprobs(h2, p["w3"], p["b3"], actions, extra)
    return -jnp.sum(weights * logp_a)


def backward(p, x, actions, weights):
    """(loss, grads-in-PARAM_ORDER) for the weighted objective."""
    loss, grads = jax.value_and_grad(weighted_loss)(p, x, actions, weights)
    return (loss,) + tuple(grads[name] for name in PARAM_ORDER)
