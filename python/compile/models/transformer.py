"""L2: decoder-only transformer for token reversal (paper App D.1).

d_model=64, 2 layers, 2 heads, causal attention -- identical architecture
to the companion paper. The module is parametrized by ``h_max``: two
compiled shape sets (h_max 16 and 32) serve every (H, M) sweep point, with
masks expressed as *data* (scalar h, m inputs) carving out the active
problem (DESIGN.md par.5).

Sequence layout (teacher forcing and rollout agree exactly):

    slot t in [0, h_max)        prompt, LEFT-padded: [0, h_max-H) = PAD,
                                [h_max-H, h_max) = prompt tokens
    slot t in [h_max, seq)      response inputs: slot h_max+j holds
                                action[j] for j <= H-2, PAD beyond

    logits at slot h_max-1+j predict action[j], j in [0, H).

Kernel placement (DESIGN.md par.7, CPU adaptation): the L1 Pallas flash
kernel runs on the ACTING path (rollout prefill) where the paper's cheap
screening signal is produced; the differentiated teacher path uses
vectorized jnp attention, because interpret-mode Pallas lowers to a
sequential grid loop that XLA-CPU cannot parallelize (on real TPU both
paths would use the Mosaic kernel). Correctness of the pallas/jnp pair is
pinned by python/tests/test_kernels.py.

Three exported entry points per shape set (artifact names in parentheses,
``revNN`` prefix = h_max):

  - ``rollout``      (revNN_rollout): autoregressive sampling fully inside
    HLO -- prefill over the prompt half with the flash kernel, then a
    ``lax.scan`` decode loop over a KV cache.
  - ``teacher_logp`` (revNN_fwd): log pi(a_j) of given actions (PPO
    ratios, re-scoring across inner epochs).
  - ``backward``     (revNN_bwd_c*): grads of -sum w_{b,j} log pi(a_{b,j}).
"""

import jax
import jax.numpy as jnp

from .. import config as C
from ..kernels import flash_attention, ref

LN_EPS = 1e-5


def seq_of(h_max):
    return 2 * h_max


def _layer_specs(l):
    d, dff = C.D_MODEL, C.D_FF
    return [
        (f"l{l}_ln1_s", (d,)), (f"l{l}_ln1_b", (d,)),
        (f"l{l}_wq", (d, d)), (f"l{l}_bq", (d,)),
        (f"l{l}_wk", (d, d)), (f"l{l}_bk", (d,)),
        (f"l{l}_wv", (d, d)), (f"l{l}_bv", (d,)),
        (f"l{l}_wo", (d, d)), (f"l{l}_bo", (d,)),
        (f"l{l}_ln2_s", (d,)), (f"l{l}_ln2_b", (d,)),
        (f"l{l}_wu", (d, C.D_FF)), (f"l{l}_bu", (C.D_FF,)),
        (f"l{l}_wd", (C.D_FF, d)), (f"l{l}_bd", (d,)),
    ]


def param_specs(h_max):
    """Parameter tensors in artifact-argument order for one shape set."""
    return (
        [("tok_emb", (C.VOCAB_IN, C.D_MODEL)), ("pos_emb", (seq_of(h_max), C.D_MODEL))]
        + [s for l in range(C.N_LAYERS) for s in _layer_specs(l)]
        + [
            ("lnf_s", (C.D_MODEL,)), ("lnf_b", (C.D_MODEL,)),
            ("w_head", (C.VOCAB, C.D_MODEL)),  # [V, D] for the fused head
            ("b_head", (C.VOCAB,)),
        ]
    )


def param_order(h_max):
    return [name for name, _ in param_specs(h_max)]


def init_params(key, h_max):
    p = {}
    specs = param_specs(h_max)
    ks = iter(jax.random.split(key, len(specs)))
    for name, shape in specs:
        k = next(ks)
        if "ln" in name and name.endswith("_s"):
            p[name] = jnp.ones(shape)
        elif len(shape) == 1:
            p[name] = jnp.zeros(shape)
        else:
            p[name] = jax.random.normal(k, shape) * 0.02
    return p


def _ln(x, s, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS) * s + b


def _split_heads(x):
    # [B, T, D] -> [B*nh, T, dh]
    b, t, _ = x.shape
    x = x.reshape(b, t, C.N_HEADS, C.D_HEAD).transpose(0, 2, 1, 3)
    return x.reshape(b * C.N_HEADS, t, C.D_HEAD)


def _merge_heads(x, b):
    t = x.shape[1]
    x = x.reshape(b, C.N_HEADS, t, C.D_HEAD).transpose(0, 2, 1, 3)
    return x.reshape(b, t, C.D_MODEL)


def _block_full(p, l, x, pad_add, use_flash):
    """Full-sequence transformer block. Returns (x_out, k_heads, v_heads)
    with k/v heads [B, nh, T, dh] so the rollout prefill can seed its KV
    cache. `use_flash` selects the L1 Pallas kernel (acting path) vs the
    vectorized jnp reference (differentiated path)."""
    b, t, _ = x.shape
    xn = _ln(x, p[f"l{l}_ln1_s"], p[f"l{l}_ln1_b"])
    q = xn @ p[f"l{l}_wq"] + p[f"l{l}_bq"]
    k = xn @ p[f"l{l}_wk"] + p[f"l{l}_bk"]
    v = xn @ p[f"l{l}_wv"] + p[f"l{l}_bv"]
    qh, kh, vh = _split_heads(q), _split_heads(k), _split_heads(v)
    pad_h = jnp.repeat(pad_add, C.N_HEADS, axis=0)
    attn = flash_attention(qh, kh, vh, pad_h) if use_flash else ref.attention(qh, kh, vh, pad_h)
    x = x + _merge_heads(attn, b) @ p[f"l{l}_wo"] + p[f"l{l}_bo"]
    xn2 = _ln(x, p[f"l{l}_ln2_s"], p[f"l{l}_ln2_b"])
    x = x + jax.nn.relu(xn2 @ p[f"l{l}_wu"] + p[f"l{l}_bu"]) @ p[f"l{l}_wd"] + p[f"l{l}_bd"]
    kh4 = kh.reshape(b, C.N_HEADS, t, C.D_HEAD)
    vh4 = vh.reshape(b, C.N_HEADS, t, C.D_HEAD)
    return x, kh4, vh4


def _prompt_pad_add(h, h_max):
    t = jnp.arange(h_max)
    return jnp.where(t >= h_max - h, 0.0, C.NEG_INF)


def _full_pad_add(h, h_max):
    """Valid keys: real prompt tokens + the H-1 teacher-forced response inputs."""
    t = jnp.arange(seq_of(h_max))
    valid = (t >= h_max - h) & (t < h_max + h - 1 + (h == 0))
    return jnp.where(valid, 0.0, C.NEG_INF)


def _vocab_add(m):
    return jnp.where(jnp.arange(C.VOCAB) < m, 0.0, C.NEG_INF)


def _teacher_tokens(prompt, actions, h, h_max):
    j = jnp.arange(h_max)
    resp_in = jnp.where(j[None, :] < h - 1, actions, C.PAD)
    return jnp.concatenate([prompt, resp_in], axis=1)


def teacher_hidden(p, prompt, actions, h, h_max):
    tokens = _teacher_tokens(prompt, actions, h, h_max)
    b = tokens.shape[0]
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :, :]
    pad_add = jnp.broadcast_to(_full_pad_add(h, h_max)[None, :], (b, seq_of(h_max)))
    for l in range(C.N_LAYERS):
        x, _, _ = _block_full(p, l, x, pad_add, use_flash=False)
    return _ln(x, p["lnf_s"], p["lnf_b"])


def teacher_logp(p, prompt, actions, h, m, h_max):
    """log pi(action_j) at every response slot: [B, h_max] (j >= H is junk,
    zeroed by the coordinator's weights)."""
    b = prompt.shape[0]
    hid = teacher_hidden(p, prompt, actions, h, h_max)
    sel = jax.lax.dynamic_slice_in_dim(hid, h_max - 1, h_max, axis=1)
    flat = sel.reshape(b * h_max, C.D_MODEL)
    acts = jnp.clip(actions, 0, C.VOCAB - 1).reshape(b * h_max)
    extra = jnp.broadcast_to(_vocab_add(m)[None, :], (b * h_max, C.VOCAB))
    logp = ref.head_action_logprobs(flat, p["w_head"], p["b_head"], acts, extra)
    return logp.reshape(b, h_max)


def weighted_loss(p, prompt, actions, weights, h, m, h_max):
    logp = teacher_logp(p, prompt, actions, h, m, h_max)
    return -jnp.sum(weights * logp)


def backward(p, prompt, actions, weights, h, m, h_max):
    loss, grads = jax.value_and_grad(weighted_loss)(
        p, prompt, actions, weights, h, m, h_max
    )
    return (loss,) + tuple(grads[name] for name in param_order(h_max))


# --------------------------------------------------------------------------
# Rollout: prefill (flash kernel) + lax.scan decode over a KV cache.
# --------------------------------------------------------------------------

def _decode_block(p, l, x, k_cache, v_cache, pos, slot_add):
    """Single-position transformer block over the KV cache."""
    b = x.shape[0]
    xn = _ln(x, p[f"l{l}_ln1_s"], p[f"l{l}_ln1_b"])
    q = (xn @ p[f"l{l}_wq"] + p[f"l{l}_bq"]).reshape(b, C.N_HEADS, C.D_HEAD)
    k = (xn @ p[f"l{l}_wk"] + p[f"l{l}_bk"]).reshape(b, C.N_HEADS, 1, C.D_HEAD)
    v = (xn @ p[f"l{l}_wv"] + p[f"l{l}_bv"]).reshape(b, C.N_HEADS, 1, C.D_HEAD)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, 0, pos, 0))
    s = jnp.einsum("bhd,bhtd->bht", q, k_cache) * (1.0 / jnp.sqrt(jnp.float32(C.D_HEAD)))
    s = s + slot_add[None, None, :]
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bht,bhtd->bhd", pr, v_cache).reshape(b, C.D_MODEL)
    x = x + ctx @ p[f"l{l}_wo"] + p[f"l{l}_bo"]
    xn2 = _ln(x, p[f"l{l}_ln2_s"], p[f"l{l}_ln2_b"])
    x = x + jax.nn.relu(xn2 @ p[f"l{l}_wu"] + p[f"l{l}_bu"]) @ p[f"l{l}_wd"] + p[f"l{l}_bd"]
    return x, k_cache, v_cache


def rollout(p, prompt, h, m, seed, h_max):
    """Sample responses autoregressively. prompt: i32[B, h_max] (left-
    padded); h, m, seed scalars. Returns (actions i32[B, h_max],
    logp f32[B, h_max]) -- entries at j >= H are sampled-but-unused."""
    b = prompt.shape[0]
    seq = seq_of(h_max)
    key = jax.random.PRNGKey(seed)

    # ---- prefill over the prompt half with the L1 flash kernel
    x = p["tok_emb"][prompt] + p["pos_emb"][None, :h_max, :]
    pad_add = jnp.broadcast_to(_prompt_pad_add(h, h_max)[None, :], (b, h_max))
    k_caches, v_caches = [], []
    for l in range(C.N_LAYERS):
        x, kh, vh = _block_full(p, l, x, pad_add, use_flash=True)
        kc = jnp.zeros((b, C.N_HEADS, seq, C.D_HEAD))
        vc = jnp.zeros((b, C.N_HEADS, seq, C.D_HEAD))
        k_caches.append(jax.lax.dynamic_update_slice(kc, kh, (0, 0, 0, 0)))
        v_caches.append(jax.lax.dynamic_update_slice(vc, vh, (0, 0, 0, 0)))
    hid = _ln(x, p["lnf_s"], p["lnf_b"])

    vocab_add = _vocab_add(m)

    def head_logits(hvec):
        return hvec @ p["w_head"].T + p["b_head"] + vocab_add[None, :]

    logits0 = head_logits(hid[:, h_max - 1, :])
    a0 = jax.random.categorical(jax.random.fold_in(key, 0), logits0)
    logp0 = jnp.take_along_axis(jax.nn.log_softmax(logits0, -1), a0[:, None], 1)[:, 0]

    k_cache = jnp.stack(k_caches)
    v_cache = jnp.stack(v_caches)
    slot_idx = jnp.arange(seq)
    prompt_valid = (slot_idx >= h_max - h) & (slot_idx < h_max)

    def step(carry, j):
        k_cache, v_cache, prev = carry
        pos = h_max + j - 1  # slot holding input token action[j-1]
        x = p["tok_emb"][prev] + p["pos_emb"][pos]
        valid = prompt_valid | ((slot_idx >= h_max) & (slot_idx <= pos))
        slot_add = jnp.where(valid, 0.0, C.NEG_INF)
        kcs, vcs = [], []
        for l in range(C.N_LAYERS):
            x, kc, vc = _decode_block(p, l, x, k_cache[l], v_cache[l], pos, slot_add)
            kcs.append(kc)
            vcs.append(vc)
        hidj = _ln(x, p["lnf_s"], p["lnf_b"])
        logits = head_logits(hidj)
        aj = jax.random.categorical(jax.random.fold_in(key, j), logits)
        lpj = jnp.take_along_axis(jax.nn.log_softmax(logits, -1), aj[:, None], 1)[:, 0]
        return (jnp.stack(kcs), jnp.stack(vcs), aj), (aj, lpj)

    js = jnp.arange(1, h_max)
    _, (acts_rest, logp_rest) = jax.lax.scan(step, (k_cache, v_cache, a0), js)

    actions = jnp.concatenate([a0[:, None], acts_rest.T], axis=1).astype(jnp.int32)
    logp = jnp.concatenate([logp0[:, None], logp_rest.T], axis=1)
    return actions, logp
