"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (and block sizes that do / don't divide evenly);
explicit tests pin the shapes the artifacts are actually compiled at.
Gradient tests compare the custom-VJP backward against jax.grad of the
reference implementation -- the CORE correctness signal for the repo.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import config as C
from compile.kernels import attention, fused_head, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ------------------------------------------------------------ fused head

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([4, 10, 25, 100]),
    d=st.sampled_from([8, 100]),
    v=st.sampled_from([10, 64]),
    bb=st.sampled_from([8, 32]),
    bv=st.sampled_from([16, 128]),
)
def test_head_logprobs_matches_ref(n, d, v, bb, bv):
    h, w, b, e = _rand(0, n, d), _rand(1, v, d), _rand(2, v), 0.1 * _rand(3, n, v)
    got = fused_head.head_logprobs(h, w, b, e, bb, bv)
    want = ref.head_logprobs(h, w, b, e)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([4, 25, 100]),
    v=st.sampled_from([10, 64]),
    bv=st.sampled_from([8, 128]),
)
def test_head_action_logprobs_matches_ref(n, v, bv):
    d = 16
    h, w, b, e = _rand(0, n, d), _rand(1, v, d), _rand(2, v), jnp.zeros((n, v))
    a = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, v)
    got = fused_head.head_action_logprobs(h, w, b, a, e, 32, bv)
    want = ref.head_action_logprobs(h, w, b, a, e)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_head_logprobs_normalized():
    h, w, b = _rand(0, 100, 100), _rand(1, 10, 100), _rand(2, 10)
    logp = fused_head.head_logprobs(h, w, b, jnp.zeros((100, 10)))
    np.testing.assert_allclose(jnp.exp(logp).sum(-1), 1.0, rtol=1e-5)


def test_head_logprobs_grads_match_ref():
    h, w, b, e = _rand(0, 25, 16), _rand(1, 10, 16), _rand(2, 10), 0.1 * _rand(3, 25, 10)

    def loss_kern(h, w, b, e):
        return jnp.sum(jnp.sin(fused_head.head_logprobs(h, w, b, e)))

    def loss_ref(h, w, b, e):
        return jnp.sum(jnp.sin(ref.head_logprobs(h, w, b, e)))

    gk = jax.grad(loss_kern, argnums=(0, 1, 2, 3))(h, w, b, e)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(h, w, b, e)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-5)


def test_head_action_logprobs_grads_match_ref():
    n, d, v = 25, 16, 10
    h, w, b = _rand(0, n, d), _rand(1, v, d), _rand(2, v)
    e = 0.1 * _rand(3, n, v)
    a = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, v)
    wts = _rand(4, n)

    def loss_kern(h, w, b, e):
        return jnp.sum(wts * fused_head.head_action_logprobs(h, w, b, a, e))

    def loss_ref(h, w, b, e):
        return jnp.sum(wts * ref.head_action_logprobs(h, w, b, a, e))

    gk = jax.grad(loss_kern, argnums=(0, 1, 2, 3))(h, w, b, e)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(h, w, b, e)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_head_logit_noise_shifts_distribution():
    # extra acts as additive logits: a huge boost on class 3 makes it argmax.
    h, w, b = _rand(0, 8, 16), _rand(1, 10, 16), _rand(2, 10)
    e = jnp.zeros((8, 10)).at[:, 3].set(50.0)
    logp = fused_head.head_logprobs(h, w, b, e)
    assert int(jnp.argmax(logp, -1).min()) == 3 and int(jnp.argmax(logp, -1).max()) == 3


def test_head_vocab_mask_zeroes_probability():
    # NEG_INF in extra implements the vocab mask for M < VOCAB.
    n, d, v, m = 16, 8, 64, 5
    h, w, b = _rand(0, n, d), _rand(1, v, d), _rand(2, v)
    e = jnp.broadcast_to(jnp.where(jnp.arange(v) < m, 0.0, C.NEG_INF)[None, :], (n, v))
    p = jnp.exp(fused_head.head_logprobs(h, w, b, e))
    assert float(p[:, m:].max()) == pytest.approx(0.0, abs=1e-30)
    np.testing.assert_allclose(p[:, :m].sum(-1), 1.0, rtol=1e-5)


# ------------------------------------------------------------ attention

@settings(max_examples=15, deadline=None)
@given(
    bh=st.sampled_from([1, 4]),
    t=st.sampled_from([8, 32, 64]),
    dh=st.sampled_from([8, 32]),
    bq=st.sampled_from([8, 32]),
    npad=st.integers(min_value=0, max_value=6),
)
def test_flash_attention_matches_ref(bh, t, dh, bq, npad):
    q, k, v = _rand(0, bh, t, dh), _rand(1, bh, t, dh), _rand(2, bh, t, dh)
    pad = jnp.where(jnp.arange(t)[None, :] < npad, C.NEG_INF, 0.0) * jnp.ones((bh, 1))
    got = attention.flash_attention(q, k, v, pad, bq, bq)
    want = ref.attention(q, k, v, pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_attention_grads_match_ref():
    bh, t, dh = 4, 64, 32
    q, k, v = _rand(0, bh, t, dh), _rand(1, bh, t, dh), _rand(2, bh, t, dh)
    pad = jnp.where(jnp.arange(t)[None, :] < 3, C.NEG_INF, 0.0) * jnp.ones((bh, 1))
    tgt = _rand(7, bh, t, dh)

    def loss_kern(q, k, v):
        return jnp.sum((attention.flash_attention(q, k, v, pad) - tgt) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum((ref.attention(q, k, v, pad) - tgt) ** 2)

    gk = jax.grad(loss_kern, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_flash_attention_causality():
    # Perturbing a future key/value must not change earlier outputs.
    bh, t, dh = 2, 32, 8
    q, k, v = _rand(0, bh, t, dh), _rand(1, bh, t, dh), _rand(2, bh, t, dh)
    pad = jnp.zeros((bh, t))
    base = attention.flash_attention(q, k, v, pad)
    k2 = k.at[:, t - 1, :].add(100.0)
    v2 = v.at[:, t - 1, :].add(-50.0)
    pert = attention.flash_attention(q, k2, v2, pad)
    np.testing.assert_allclose(base[:, : t - 1], pert[:, : t - 1], rtol=1e-6)
    assert float(jnp.abs(base[:, t - 1] - pert[:, t - 1]).max()) > 1e-3


def test_flash_attention_pad_keys_ignored():
    bh, t, dh, npad = 2, 16, 8, 4
    q, k, v = _rand(0, bh, t, dh), _rand(1, bh, t, dh), _rand(2, bh, t, dh)
    pad = jnp.where(jnp.arange(t)[None, :] < npad, C.NEG_INF, 0.0) * jnp.ones((bh, 1))
    base = attention.flash_attention(q, k, v, pad)
    k2 = k.at[:, :npad].set(_rand(5, bh, npad, dh) * 7.0)
    v2 = v.at[:, :npad].set(_rand(6, bh, npad, dh) * 7.0)
    pert = attention.flash_attention(q, k2, v2, pad)
    # Outputs at non-pad query positions are unchanged.
    np.testing.assert_allclose(base[:, npad:], pert[:, npad:], rtol=1e-5, atol=1e-6)
