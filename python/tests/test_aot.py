"""AOT export surface: signatures, manifest consistency, HLO text validity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, config as C
from compile.models import mlp, transformer

jax.config.update("jax_platform_name", "cpu")


def test_build_artifacts_signatures_consistent():
    arts = aot.build_artifacts()
    # every expected artifact present
    expected = {"mnist_fwd", "mnist_fwd_eval"}
    expected |= {f"mnist_bwd_c{c}" for c in C.MNIST_BWD_CAPS}
    for hm in C.REV_SETS:
        expected |= {f"rev{hm}_rollout", f"rev{hm}_fwd"}
        expected |= {f"rev{hm}_bwd_c{c}" for c in C.REV_BWD_CAPS}
    assert set(arts) == expected
    for name, (fn, in_specs, in_sigs, out_sigs) in arts.items():
        assert len(in_specs) == len(in_sigs), name
        for spec_, sig in zip(in_specs, in_sigs):
            assert list(spec_.shape) == sig["shape"], (name, sig)


def test_param_sigs_match_model_order():
    arts = aot.build_artifacts()
    _, _, in_sigs, _ = arts["mnist_fwd"]
    assert [s["name"] for s in in_sigs[: len(mlp.PARAM_ORDER)]] == mlp.PARAM_ORDER
    for hm in C.REV_SETS:
        _, _, in_sigs, _ = arts[f"rev{hm}_rollout"]
        order = transformer.param_order(hm)
        assert [s["name"] for s in in_sigs[: len(order)]] == order


def test_lowered_outputs_match_declared_sigs():
    # Evaluate the small MNIST fwd artifact function directly and compare
    # against its declared output signature.
    arts = aot.build_artifacts()
    fn, in_specs, _, out_sigs = arts["mnist_fwd"]
    args = [
        jnp.zeros(s.shape, s.dtype)
        if s.dtype == jnp.int32
        else 0.01 * jnp.ones(s.shape, s.dtype)
        for s in in_specs
    ]
    outs = fn(*args)
    assert len(outs) == len(out_sigs)
    for o, sig in zip(outs, out_sigs):
        assert list(o.shape) == sig["shape"]


def test_hlo_text_lowering_roundtrip():
    # Lower the smallest bwd artifact and check the HLO text parses basic
    # expectations: it is an ENTRY module with the right parameter count.
    arts = aot.build_artifacts()
    fn, in_specs, in_sigs, _ = arts["mnist_bwd_c4"]
    lowered = jax.jit(fn).lower(*in_specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # count parameters of the ENTRY computation only (fusions have their own)
    entry = text[text.index("ENTRY") :]
    entry_block = entry[: entry.index("\n}")]
    assert entry_block.count("parameter(") == len(in_sigs)


def test_manifest_on_disk_if_built():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        return  # artifacts not built yet; covered by make test ordering
    man = json.load(open(path))
    assert man["constants"]["h_max"] == C.H_MAX
    assert man["constants"]["vocab"] == C.VOCAB
    for name, art in man["artifacts"].items():
        apath = os.path.join(os.path.dirname(path), art["file"])
        assert os.path.exists(apath), name


def test_init_rules_cover_all_params():
    man_models = {
        "mnist": aot._init_rules(mlp.PARAM_SPECS, "mnist"),
        "reversal": aot._init_rules(transformer.param_specs(16), "reversal"),
    }
    assert [r["name"] for r in man_models["mnist"]] == mlp.PARAM_ORDER
    assert [r["name"] for r in man_models["reversal"]] == transformer.param_order(16)
    for rules in man_models.values():
        for r in rules:
            assert r["kind"] in ("normal", "zeros", "ones")
            if r["kind"] == "normal":
                assert r["scale"] > 0
    # LN scales are ones, LN biases zeros
    rev = {r["name"]: r for r in man_models["reversal"]}
    assert rev["l0_ln1_s"]["kind"] == "ones"
    assert rev["l0_ln1_b"]["kind"] == "zeros"
    assert rev["lnf_s"]["kind"] == "ones"
