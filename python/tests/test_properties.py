"""Hypothesis property tests across the L2 models (invariants the L3
coordinator relies on, beyond the fixed-case tests)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import config as C
from compile.models import mlp, transformer as tf

jax.config.update("jax_platform_name", "cpu")

HM = 16


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_mlp_grads_scale_linearly_in_weights(b, seed):
    # grad(c * w) == c * grad(w): the scaling the trainer's /B normalization
    # and Fig-3 cost model both assume.
    key = jax.random.PRNGKey(seed)
    p = mlp.init_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, C.MNIST_IN))
    a = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, 10)
    w = jax.random.normal(jax.random.fold_in(key, 3), (b,))
    g1 = mlp.backward(p, x, a, w)[1:]
    g3 = mlp.backward(p, x, a, 3.0 * w)[1:]
    for u, v in zip(g1, g3):
        np.testing.assert_allclose(3.0 * u, v, rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=8),
    perm_seed=st.integers(min_value=0, max_value=100),
)
def test_mlp_grads_invariant_to_sample_order(b, perm_seed):
    # the batcher may pack kept samples in any order
    key = jax.random.PRNGKey(7)
    p = mlp.init_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, C.MNIST_IN))
    a = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, 10)
    w = jax.random.normal(jax.random.fold_in(key, 3), (b,))
    perm = jax.random.permutation(jax.random.PRNGKey(perm_seed), b)
    g = mlp.backward(p, x, a, w)[1:]
    gp = mlp.backward(p, x[perm], a[perm], w[perm])[1:]
    for u, v in zip(g, gp):
        np.testing.assert_allclose(u, v, rtol=1e-4, atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(
    h=st.integers(min_value=2, max_value=12),
    m=st.sampled_from([2, 4, 16, 64]),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_rollout_teacher_consistency_random_hm(h, m, seed):
    # the decode path and the teacher path agree for ANY (h, m) point the
    # sweep drivers might visit
    p = tf.init_params(jax.random.PRNGKey(3), HM)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (2, h), 0, m)
    pad = jnp.full((2, HM - h), C.PAD, jnp.int32)
    prompt = jnp.concatenate([pad, toks.astype(jnp.int32)], axis=1)
    actions, lp_roll = tf.rollout(p, prompt, h, m, seed, HM)
    lp_teach = tf.teacher_logp(p, prompt, actions, h, m, HM)
    np.testing.assert_allclose(
        lp_roll[:, :h], lp_teach[:, :h], rtol=2e-4, atol=2e-4
    )
    assert int(actions[:, :h].max()) < m


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000))
def test_transformer_grads_additive_in_weights(seed):
    # grad(w1) + grad(w2) == grad(w1 + w2): lets the coordinator split a
    # gated batch across capacity buckets without bias
    p = tf.init_params(jax.random.PRNGKey(1), HM)
    h, m = 4, 2
    toks = jax.random.randint(jax.random.PRNGKey(seed), (2, h), 0, m)
    pad = jnp.full((2, HM - h), C.PAD, jnp.int32)
    prompt = jnp.concatenate([pad, toks.astype(jnp.int32)], axis=1)
    actions = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, HM), 0, m)
    k = jax.random.PRNGKey(seed + 2)
    w1 = jnp.zeros((2, HM)).at[:, :h].set(jax.random.normal(k, (2, h)))
    w2 = jnp.zeros((2, HM)).at[:, :h].set(
        jax.random.normal(jax.random.fold_in(k, 1), (2, h))
    )
    g1 = tf.backward(p, prompt, actions, w1, h, m, HM)[1:]
    g2 = tf.backward(p, prompt, actions, w2, h, m, HM)[1:]
    g12 = tf.backward(p, prompt, actions, w1 + w2, h, m, HM)[1:]
    for a, b, c in zip(g1, g2, g12):
        np.testing.assert_allclose(np.array(a) + np.array(b), c, rtol=1e-3, atol=1e-4)
