"""L2 MNIST MLP: distribution sanity, gradient correctness, weight algebra."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import config as C
from compile.kernels import ref
from compile.models import mlp

jax.config.update("jax_platform_name", "cpu")


def _setup(b=16, seed=0):
    p = mlp.init_params(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, C.MNIST_IN))
    return p, x


def _ref_logprobs(p, x, noise):
    h1 = jax.nn.relu(x @ p["w1"] + p["b1"])
    h2 = jax.nn.relu(h1 @ p["w2"] + p["b2"])
    return ref.head_logprobs(h2, p["w3"], p["b3"], noise)


def test_forward_is_normalized_distribution():
    p, x = _setup()
    logp = mlp.forward_logprobs(p, x, jnp.zeros((16, 10)))
    assert logp.shape == (16, 10)
    np.testing.assert_allclose(jnp.exp(logp).sum(-1), 1.0, rtol=1e-5)
    assert float(logp.max()) <= 0.0


def test_forward_matches_pure_ref():
    p, x = _setup()
    noise = 0.3 * jax.random.normal(jax.random.PRNGKey(7), (16, 10))
    got = mlp.forward_logprobs(p, x, noise)
    want = _ref_logprobs(p, x, noise)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_backward_matches_jax_grad_of_ref():
    p, x = _setup(b=8)
    a = jax.random.randint(jax.random.PRNGKey(3), (8,), 0, 10)
    w = jax.random.normal(jax.random.PRNGKey(4), (8,))

    def ref_loss(p):
        logp = _ref_logprobs(p, x, jnp.zeros((8, 10)))
        lp_a = jnp.take_along_axis(logp, a[:, None], 1)[:, 0]
        return -jnp.sum(w * lp_a)

    out = mlp.backward(p, x, a, w)
    loss, grads = out[0], out[1:]
    ref_l, ref_g = jax.value_and_grad(ref_loss)(p)
    np.testing.assert_allclose(loss, ref_l, rtol=1e-5)
    for g, name in zip(grads, mlp.PARAM_ORDER):
        np.testing.assert_allclose(g, ref_g[name], rtol=1e-4, atol=1e-6)


def test_zero_weights_give_zero_grads():
    p, x = _setup(b=4)
    a = jnp.array([0, 1, 2, 3])
    out = mlp.backward(p, x, a, jnp.zeros(4))
    for g in out[1:]:
        assert float(jnp.abs(g).max()) == 0.0


def test_backward_is_linear_in_weights():
    # grad(w) + grad(w') == grad(w + w'): the property that lets the L3
    # batcher split a batch across capacity buckets without bias.
    p, x = _setup(b=8)
    a = jax.random.randint(jax.random.PRNGKey(3), (8,), 0, 10)
    w1 = jax.random.normal(jax.random.PRNGKey(5), (8,))
    w2 = jax.random.normal(jax.random.PRNGKey(6), (8,))
    g1 = mlp.backward(p, x, a, w1)[1:]
    g2 = mlp.backward(p, x, a, w2)[1:]
    g12 = mlp.backward(p, x, a, w1 + w2)[1:]
    for a_, b_, c_ in zip(g1, g2, g12):
        np.testing.assert_allclose(a_ + b_, c_, rtol=1e-4, atol=1e-5)


def test_padding_samples_with_zero_weight_is_exact():
    # Packing k kept samples into a larger bucket with zero-weight padding
    # must give identical grads -- the L3 bucketed-backward invariant.
    p, x = _setup(b=8)
    a = jax.random.randint(jax.random.PRNGKey(3), (8,), 0, 10)
    w = jax.random.normal(jax.random.PRNGKey(5), (8,))
    g_full = mlp.backward(p, x, a, w)[1:]
    xp = jnp.concatenate([x, jax.random.normal(jax.random.PRNGKey(9), (8, C.MNIST_IN))])
    ap = jnp.concatenate([a, jnp.zeros(8, jnp.int32)])
    wp = jnp.concatenate([w, jnp.zeros(8)])
    g_pad = mlp.backward(p, xp, ap, wp)[1:]
    for gf, gp in zip(g_full, g_pad):
        np.testing.assert_allclose(gf, gp, rtol=1e-4, atol=1e-6)


def test_gradient_step_improves_weighted_objective():
    p, x = _setup(b=32)
    a = jax.random.randint(jax.random.PRNGKey(3), (32,), 0, 10)
    w = jnp.ones(32)
    out = mlp.backward(p, x, a, w)
    loss0, grads = out[0], out[1:]
    lr = 1e-2
    p2 = {n: p[n] - lr * g for n, g in zip(mlp.PARAM_ORDER, grads)}
    loss1 = mlp.backward(p2, x, a, w)[0]
    assert float(loss1) < float(loss0)
