"""L2 transformer: rollout/teacher consistency, masking invariants, grads.

The strongest check is rollout-vs-teacher agreement: the KV-cache decode
path (plain jnp single-query attention) and the teacher-forced path (L1
flash-attention kernel + fused head) are independent implementations of
the same policy; their log-probs on the same actions must coincide.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config as C
from compile.models import transformer as tf

jax.config.update("jax_platform_name", "cpu")


HM = 16  # fast shape set; 32 covered by test_rollout_matches_teacher_logp_big


def _params(seed=0, hm=HM):
    return tf.init_params(jax.random.PRNGKey(seed), hm)


def _prompt(b, h, m, seed=1, hm=HM):
    """Left-padded prompt batch i32[b, hm]."""
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, h), 0, m)
    pad = jnp.full((b, hm - h), C.PAD, dtype=jnp.int32)
    return jnp.concatenate([pad, toks.astype(jnp.int32)], axis=1)


@pytest.mark.parametrize("h,m", [(5, 2), (10, 2), (3, 8), (16, 64)])
def test_rollout_matches_teacher_logp(h, m):
    p = _params()
    prompt = _prompt(4, h, m)
    actions, logp_roll = tf.rollout(p, prompt, h, m, 42, HM)
    logp_teach = tf.teacher_logp(p, prompt, actions, h, m, HM)
    np.testing.assert_allclose(
        logp_roll[:, :h], logp_teach[:, :h], rtol=1e-4, atol=1e-4
    )


def test_rollout_respects_vocab_mask():
    p = _params()
    h, m = 8, 3
    actions, _ = tf.rollout(p, _prompt(16, h, m), h, m, 7, HM)
    assert int(actions.max()) < m
    assert int(actions.min()) >= 0


def test_rollout_is_deterministic_in_seed():
    p = _params()
    h, m = 6, 4
    a1, l1 = tf.rollout(p, _prompt(4, h, m), h, m, 3, HM)
    a2, l2 = tf.rollout(p, _prompt(4, h, m), h, m, 3, HM)
    a3, _ = tf.rollout(p, _prompt(4, h, m), h, m, 4, HM)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_allclose(l1, l2)
    assert not np.array_equal(np.array(a1), np.array(a3))  # different seed differs


def test_teacher_logp_is_valid_logprob():
    p = _params()
    h, m = 10, 2
    prompt = _prompt(4, h, m)
    actions = jax.random.randint(jax.random.PRNGKey(2), (4, HM), 0, m)
    logp = tf.teacher_logp(p, prompt, actions, h, m, HM)
    assert float(logp[:, :h].max()) <= 1e-6
    # M=2: logp must be >= log of a tiny floor given finite logits
    assert np.isfinite(np.array(logp[:, :h])).all()


def test_junk_action_slots_do_not_affect_valid_logp():
    # Actions at j >= H-1 are not inputs to any valid position; perturbing
    # them must leave logp at j < H unchanged (mask correctness).
    p = _params()
    h, m = 6, 4
    prompt = _prompt(4, h, m)
    actions = jax.random.randint(jax.random.PRNGKey(2), (4, HM), 0, m)
    base = tf.teacher_logp(p, prompt, actions, h, m, HM)
    junk = actions.at[:, h:].set((actions[:, h:] + 1) % m)
    pert = tf.teacher_logp(p, prompt, junk, h, m, HM)
    np.testing.assert_allclose(base[:, :h], pert[:, :h], rtol=1e-5, atol=1e-6)


def test_prompt_pad_slots_do_not_affect_logp():
    # Tokens in the left-pad region are masked as keys: replacing their ids
    # must not change anything (they are PAD anyway, but verify the mask,
    # not the convention).
    p = _params()
    h, m = 6, 4
    prompt = _prompt(4, h, m)
    actions = jax.random.randint(jax.random.PRNGKey(2), (4, HM), 0, m)
    base = tf.teacher_logp(p, prompt, actions, h, m, HM)
    vandal = prompt.at[:, : HM - h].set(0)  # real token id in pad region
    pert = tf.teacher_logp(p, vandal, actions, h, m, HM)
    np.testing.assert_allclose(base[:, :h], pert[:, :h], rtol=1e-5, atol=1e-6)


def test_backward_matches_jax_grad():
    p = _params()
    h, m = 4, 2
    b = 2
    prompt = _prompt(b, h, m)
    actions = jax.random.randint(jax.random.PRNGKey(2), (b, HM), 0, m)
    w = jnp.zeros((b, HM)).at[:, :h].set(
        jax.random.normal(jax.random.PRNGKey(3), (b, h))
    )
    out = tf.backward(p, prompt, actions, w, h, m, HM)
    loss, grads = out[0], out[1:]

    def loss_fn(p):
        return tf.weighted_loss(p, prompt, actions, w, h, m, HM)

    ref_l, ref_g = jax.value_and_grad(loss_fn)(p)
    np.testing.assert_allclose(loss, ref_l, rtol=1e-5)
    nonzero = 0
    for g, name in zip(grads, tf.param_order(HM)):
        np.testing.assert_allclose(g, ref_g[name], rtol=1e-4, atol=1e-6)
        nonzero += int(float(jnp.abs(g).max()) > 0)
    assert nonzero > len(tf.param_order(HM)) // 2  # gradient actually flows


def test_zero_weights_give_zero_grads():
    p = _params()
    h, m = 4, 2
    prompt = _prompt(2, h, m)
    actions = jnp.zeros((2, HM), jnp.int32)
    out = tf.backward(p, prompt, actions, jnp.zeros((2, HM)), h, m, HM)
    for g in out[1:]:
        assert float(jnp.abs(g).max()) == 0.0


def test_gradient_step_increases_weighted_logp():
    # One ascent step on -loss must raise the log-prob of up-weighted actions.
    p = _params()
    h, m = 5, 2
    prompt = _prompt(4, h, m)
    actions, _ = tf.rollout(p, prompt, h, m, 11, HM)
    w = jnp.zeros((4, HM)).at[:, :h].set(1.0)
    out = tf.backward(p, prompt, actions, w, h, m, HM)
    grads = out[1:]
    p2 = {n: p[n] - 0.003 * g for n, g in zip(tf.param_order(HM), grads)}
    lp0 = tf.teacher_logp(p, prompt, actions, h, m, HM)[:, :h].sum()
    lp1 = tf.teacher_logp(p2, prompt, actions, h, m, HM)[:, :h].sum()
    assert float(lp1) > float(lp0)


def test_rollout_matches_teacher_logp_big_set():
    # the h_max=32 compiled set must agree with itself too
    hm = 32
    p = _params(hm=hm)
    h, m = 20, 4
    prompt = _prompt(2, h, m, hm=hm)
    actions, logp_roll = tf.rollout(p, prompt, h, m, 5, hm)
    logp_teach = tf.teacher_logp(p, prompt, actions, h, m, hm)
    np.testing.assert_allclose(logp_roll[:, :h], logp_teach[:, :h], rtol=1e-4, atol=1e-4)
